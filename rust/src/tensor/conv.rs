//! Golden CNN operators: direct convolution, pooling, ReLU.
//!
//! These are the scalar reference implementations every other path in the
//! repo is validated against — the cycle simulator's functional output, the
//! PJRT-executed JAX/Pallas artifacts, and the optimized forward pass.

use super::Tensor;

/// Convolution hyper-parameters (square kernels, symmetric padding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvSpec {
    pub stride: usize,
    pub pad: usize,
}

impl Default for ConvSpec {
    fn default() -> Self {
        // The paper's optimized case: 3x3 kernel, unit stride, pad 1.
        ConvSpec { stride: 1, pad: 1 }
    }
}

/// Output spatial size for one dimension.
pub fn out_dim(in_dim: usize, k: usize, spec: ConvSpec) -> usize {
    assert!(in_dim + 2 * spec.pad >= k, "kernel larger than padded input");
    (in_dim + 2 * spec.pad - k) / spec.stride + 1
}

/// Direct 2-D convolution (cross-correlation, as in all CNN frameworks).
///
/// `input` is `[C_in, H, W]`, `weight` is `[K_out, C_in, KH, KW]`, optional
/// `bias` is `[K_out]`. Returns `[K_out, H_out, W_out]`.
pub fn conv2d(input: &Tensor, weight: &Tensor, bias: Option<&[f32]>, spec: ConvSpec) -> Tensor {
    assert_eq!(input.ndim(), 3, "input must be [C,H,W]");
    assert_eq!(weight.ndim(), 4, "weight must be [K,C,KH,KW]");
    let (c_in, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let (k_out, wc, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(c_in, wc, "channel mismatch: input {c_in} vs weight {wc}");
    if let Some(b) = bias {
        assert_eq!(b.len(), k_out, "bias length mismatch");
    }
    let h_out = out_dim(h, kh, spec);
    let w_out = out_dim(w, kw, spec);

    let mut out = Tensor::zeros(&[k_out, h_out, w_out]);
    for k in 0..k_out {
        let b = bias.map_or(0.0, |b| b[k]);
        for oh in 0..h_out {
            for ow in 0..w_out {
                let mut acc = b;
                for c in 0..c_in {
                    for i in 0..kh {
                        // Signed arithmetic handles the padded border.
                        let ih = (oh * spec.stride + i) as isize - spec.pad as isize;
                        if ih < 0 || ih >= h as isize {
                            continue;
                        }
                        for j in 0..kw {
                            let iw = (ow * spec.stride + j) as isize - spec.pad as isize;
                            if iw < 0 || iw >= w as isize {
                                continue;
                            }
                            acc += input.at3(c, ih as usize, iw as usize) * weight.at4(k, c, i, j);
                        }
                    }
                }
                *out.at3_mut(k, oh, ow) = acc;
            }
        }
    }
    out
}

/// Zero-pad a `[C, H, W]` tensor by `pad` on every spatial border (the
/// explicit form of a conv's implicit padding — used by the polyphase
/// mapper for padded strided convs).
pub fn pad_input(input: &Tensor, pad: usize) -> Tensor {
    assert_eq!(input.ndim(), 3, "input must be [C,H,W]");
    if pad == 0 {
        return input.clone();
    }
    let (c, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let mut out = Tensor::zeros(&[c, h + 2 * pad, w + 2 * pad]);
    for ci in 0..c {
        for i in 0..h {
            for j in 0..w {
                *out.at3_mut(ci, i + pad, j + pad) = input.at3(ci, i, j);
            }
        }
    }
    out
}

/// In-place ReLU; returns the count of elements clamped to zero (the
/// post-processing unit's zero-detection statistic).
pub fn relu_inplace(t: &mut Tensor) -> usize {
    let mut zeroed = 0;
    for x in t.data_mut() {
        if *x < 0.0 {
            *x = 0.0;
            zeroed += 1;
        }
    }
    zeroed
}

/// 2x2 max-pool with stride 2 (VGG's only pooling shape).
/// Truncates odd trailing rows/cols like the original VGG implementation.
pub fn maxpool2x2(input: &Tensor) -> Tensor {
    assert_eq!(input.ndim(), 3, "input must be [C,H,W]");
    let (c_n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let (ho, wo) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[c_n, ho, wo]);
    for c in 0..c_n {
        for oh in 0..ho {
            for ow in 0..wo {
                let m = input
                    .at3(c, 2 * oh, 2 * ow)
                    .max(input.at3(c, 2 * oh, 2 * ow + 1))
                    .max(input.at3(c, 2 * oh + 1, 2 * ow))
                    .max(input.at3(c, 2 * oh + 1, 2 * ow + 1));
                *out.at3_mut(c, oh, ow) = m;
            }
        }
    }
    out
}

/// Global average pool: `[C,H,W]` → `[C]`.
pub fn global_avg_pool(input: &Tensor) -> Vec<f32> {
    assert_eq!(input.ndim(), 3);
    let (c_n, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let denom = (h * w) as f32;
    (0..c_n)
        .map(|c| {
            let mut s = 0.0;
            for i in 0..h {
                for j in 0..w {
                    s += input.at3(c, i, j);
                }
            }
            s / denom
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig 6 example: 5x5 input, pad 1, 3x3 kernel → 5x5 output.
    #[test]
    fn paper_example_shape() {
        let spec = ConvSpec { stride: 1, pad: 1 };
        assert_eq!(out_dim(5, 3, spec), 5);
        let input = Tensor::zeros(&[1, 5, 5]);
        let weight = Tensor::zeros(&[1, 1, 3, 3]);
        let out = conv2d(&input, &weight, None, spec);
        assert_eq!(out.shape(), &[1, 5, 5]);
    }

    #[test]
    fn identity_kernel_passes_through() {
        // Center-one 3x3 kernel reproduces the input exactly.
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        *w.at4_mut(0, 0, 1, 1) = 1.0;
        let input = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let out = conv2d(&input, &w, None, ConvSpec::default());
        assert_eq!(out.data(), input.data());
    }

    #[test]
    fn known_small_convolution() {
        // 1x3x3 input, all-ones 3x3 kernel, pad 1: each output = sum of the
        // 3x3 neighbourhood (with zero padding).
        let input = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let out = conv2d(&input, &w, None, ConvSpec::default());
        // Center = sum of all = 45; corner (0,0) = 1+2+4+5 = 12.
        assert_eq!(out.at3(0, 1, 1), 45.0);
        assert_eq!(out.at3(0, 0, 0), 12.0);
        assert_eq!(out.at3(0, 2, 2), 5.0 + 6.0 + 8.0 + 9.0);
    }

    #[test]
    fn bias_is_added_per_output_channel() {
        let input = Tensor::zeros(&[1, 4, 4]);
        let w = Tensor::zeros(&[2, 1, 3, 3]);
        let out = conv2d(&input, &w, Some(&[1.5, -2.0]), ConvSpec::default());
        assert!(out.data()[..16].iter().all(|&x| x == 1.5));
        assert!(out.data()[16..].iter().all(|&x| x == -2.0));
    }

    #[test]
    fn multi_channel_accumulates() {
        // Two input channels of ones, 1x1 kernel of ones → output 2.
        let input = Tensor::from_vec(&[2, 2, 2], vec![1.0; 8]);
        let w = Tensor::from_vec(&[1, 2, 1, 1], vec![1.0, 1.0]);
        let out = conv2d(&input, &w, None, ConvSpec { stride: 1, pad: 0 });
        assert_eq!(out.shape(), &[1, 2, 2]);
        assert!(out.data().iter().all(|&x| x == 2.0));
    }

    #[test]
    fn stride_two_downsamples() {
        let input = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let mut w = Tensor::zeros(&[1, 1, 1, 1]);
        *w.at4_mut(0, 0, 0, 0) = 1.0;
        let out = conv2d(&input, &w, None, ConvSpec { stride: 2, pad: 0 });
        assert_eq!(out.shape(), &[1, 2, 2]);
        assert_eq!(out.data(), &[0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn relu_zeroes_negatives_and_counts() {
        let mut t = Tensor::from_vec(&[4], vec![-1.0, 2.0, -3.0, 0.0]);
        let zeroed = relu_inplace(&mut t);
        assert_eq!(zeroed, 2);
        assert_eq!(t.data(), &[0.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn maxpool_takes_window_max() {
        let input = Tensor::from_vec(&[1, 4, 4], (0..16).map(|i| i as f32).collect());
        let out = maxpool2x2(&input);
        assert_eq!(out.shape(), &[1, 2, 2]);
        assert_eq!(out.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn pad_input_matches_implicit_padding() {
        // conv(x, w, pad p) == conv(pad(x, p), w, pad 0), any stride.
        let input = Tensor::from_vec(&[1, 3, 3], (1..=9).map(|v| v as f32).collect());
        let weight = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        for stride in [1usize, 2] {
            let spec = ConvSpec { stride, pad: 1 };
            let implicit = conv2d(&input, &weight, None, spec);
            let explicit = conv2d(
                &pad_input(&input, 1),
                &weight,
                None,
                ConvSpec { stride, pad: 0 },
            );
            assert_eq!(implicit.shape(), explicit.shape());
            assert_eq!(implicit.data(), explicit.data());
        }
        // pad 0 is the identity.
        assert_eq!(pad_input(&input, 0).data(), input.data());
    }

    #[test]
    fn global_avg_pool_averages() {
        let input = Tensor::from_vec(&[2, 2, 2], vec![1.0, 1.0, 1.0, 1.0, 2.0, 2.0, 2.0, 2.0]);
        assert_eq!(global_avg_pool(&input), vec![1.0, 2.0]);
    }
}
