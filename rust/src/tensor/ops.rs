//! Elementwise and linear-algebra helpers on [`Tensor`].

use super::Tensor;

/// Matrix multiply: `[M,K] x [K,N] -> [M,N]` (used by the FC layers and the
/// im2col-based fast conv in the performance path).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "inner dims mismatch {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    // ikj loop order: streams b rows, good cache behaviour without blocking.
    for i in 0..m {
        for p in 0..k {
            let av = ad[i * k + p];
            if av == 0.0 {
                continue; // weight sparsity shortcut
            }
            let brow = &bd[p * n..(p + 1) * n];
            let orow = &mut od[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// im2col: unfold `[C,H,W]` into a `[C*KH*KW, H_out*W_out]` patch matrix so
/// conv becomes a single matmul. Used by the optimized forward path.
pub fn im2col(input: &Tensor, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor {
    assert_eq!(input.ndim(), 3);
    let (c_in, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let h_out = (h + 2 * pad - kh) / stride + 1;
    let w_out = (w + 2 * pad - kw) / stride + 1;
    let mut out = Tensor::zeros(&[c_in * kh * kw, h_out * w_out]);
    let od = out.data_mut();
    let cols = h_out * w_out;
    for c in 0..c_in {
        for i in 0..kh {
            for j in 0..kw {
                let row = (c * kh + i) * kw + j;
                for oh in 0..h_out {
                    let ih = (oh * stride + i) as isize - pad as isize;
                    if ih < 0 || ih >= h as isize {
                        continue;
                    }
                    for ow in 0..w_out {
                        let iw = (ow * stride + j) as isize - pad as isize;
                        if iw < 0 || iw >= w as isize {
                            continue;
                        }
                        od[row * cols + oh * w_out + ow] =
                            input.at3(c, ih as usize, iw as usize);
                    }
                }
            }
        }
    }
    out
}

/// Convolution via im2col + matmul. Numerically identical to
/// [`super::conv::conv2d`] (checked in tests) but much faster for the
/// whole-network forward pass.
pub fn conv2d_im2col(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    spec: super::conv::ConvSpec,
) -> Tensor {
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(c_in, input.shape()[0]);
    let h_out = super::conv::out_dim(input.shape()[1], kh, spec);
    let w_out = super::conv::out_dim(input.shape()[2], kw, spec);
    let patches = im2col(input, kh, kw, spec.stride, spec.pad);
    let wmat = weight.clone().reshape(&[k_out, c_in * kh * kw]);
    let mut out = matmul(&wmat, &patches); // [K, H_out*W_out]
    if let Some(b) = bias {
        let od = out.data_mut();
        let cols = h_out * w_out;
        for (k, &bv) in b.iter().enumerate() {
            for x in &mut od[k * cols..(k + 1) * cols] {
                *x += bv;
            }
        }
    }
    out.reshape(&[k_out, h_out, w_out])
}

/// Multithreaded im2col convolution: output channels are split across
/// `threads` std threads (the patch matrix is shared read-only). This is
/// the coordinator's fast functional path when PJRT artifacts are not in
/// play. Numerically identical to [`conv2d_im2col`].
pub fn conv2d_im2col_mt(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    spec: super::conv::ConvSpec,
    threads: usize,
) -> Tensor {
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(c_in, input.shape()[0]);
    let threads = threads.max(1).min(k_out);
    if threads == 1 {
        return conv2d_im2col(input, weight, bias, spec);
    }
    let h_out = super::conv::out_dim(input.shape()[1], kh, spec);
    let w_out = super::conv::out_dim(input.shape()[2], kw, spec);
    let cols = h_out * w_out;
    let kdim = c_in * kh * kw;
    let patches = im2col(input, kh, kw, spec.stride, spec.pad);
    let pd = patches.data();
    let wd = weight.data();

    let mut out = vec![0.0f32; k_out * cols];
    let chunk = k_out.div_ceil(threads);
    std::thread::scope(|s| {
        for (ti, out_chunk) in out.chunks_mut(chunk * cols).enumerate() {
            let k_lo = ti * chunk;
            s.spawn(move || {
                for (ki, orow) in out_chunk.chunks_mut(cols).enumerate() {
                    let k = k_lo + ki;
                    if let Some(b) = bias {
                        orow.fill(b[k]);
                    }
                    for p in 0..kdim {
                        let av = wd[k * kdim + p];
                        if av == 0.0 {
                            continue;
                        }
                        let prow = &pd[p * cols..(p + 1) * cols];
                        for (o, &pv) in orow.iter_mut().zip(prow) {
                            *o += av * pv;
                        }
                    }
                }
            });
        }
    });
    Tensor::from_vec(&[k_out, h_out, w_out], out)
}

/// Sum of all elements.
pub fn sum(t: &Tensor) -> f32 {
    t.data().iter().sum()
}

/// Scale all elements in place.
pub fn scale_inplace(t: &mut Tensor, s: f32) {
    for x in t.data_mut() {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv::{conv2d, ConvSpec};
    use crate::util::rng::Pcg32;

    fn random_tensor(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n)
            .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
            .collect();
        Tensor::from_vec(shape, data)
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let eye = Tensor::from_vec(&[3, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul(&a, &eye).data(), a.data());
    }

    /// Property test: im2col+matmul conv equals direct conv on random
    /// shapes, sparsities and paddings.
    #[test]
    fn conv_im2col_matches_direct_randomized() {
        let mut rng = Pcg32::seeded(77);
        for case in 0..40 {
            let c_in = rng.range(1, 5);
            let k_out = rng.range(1, 5);
            let h = rng.range(3, 10);
            let w = rng.range(3, 10);
            let k = [1, 3, 5][rng.range(0, 3)];
            let pad = rng.range(0, k / 2 + 2);
            let stride = rng.range(1, 3);
            if h + 2 * pad < k || w + 2 * pad < k {
                continue;
            }
            let spec = ConvSpec { stride, pad };
            let input = random_tensor(&mut rng, &[c_in, h, w], 0.6);
            let weight = random_tensor(&mut rng, &[k_out, c_in, k, k], 0.5);
            let bias: Vec<f32> = (0..k_out).map(|_| rng.normal()).collect();
            let a = conv2d(&input, &weight, Some(&bias), spec);
            let b = conv2d_im2col(&input, &weight, Some(&bias), spec);
            assert!(
                a.allclose(&b, 1e-4, 1e-4),
                "case {case}: mismatch {} (cin={c_in} k={k} pad={pad} stride={stride})",
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn conv_mt_matches_single_thread() {
        let mut rng = Pcg32::seeded(88);
        for threads in [1usize, 2, 3, 8] {
            let input = random_tensor(&mut rng, &[3, 9, 9], 0.7);
            let weight = random_tensor(&mut rng, &[7, 3, 3, 3], 0.5);
            let bias: Vec<f32> = (0..7).map(|_| rng.normal()).collect();
            let spec = ConvSpec::default();
            let a = conv2d_im2col(&input, &weight, Some(&bias), spec);
            let b = conv2d_im2col_mt(&input, &weight, Some(&bias), spec, threads);
            assert!(
                a.allclose(&b, 1e-6, 1e-6),
                "threads={threads}: {}",
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn conv_mt_more_threads_than_channels() {
        let mut rng = Pcg32::seeded(89);
        let input = random_tensor(&mut rng, &[2, 5, 5], 1.0);
        let weight = random_tensor(&mut rng, &[2, 2, 3, 3], 1.0);
        let a = conv2d_im2col(&input, &weight, None, ConvSpec::default());
        let b = conv2d_im2col_mt(&input, &weight, None, ConvSpec::default(), 16);
        assert!(a.allclose(&b, 1e-6, 1e-6));
    }

    #[test]
    fn sum_and_scale() {
        let mut t = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        assert_eq!(sum(&t), 6.0);
        scale_inplace(&mut t, 2.0);
        assert_eq!(t.data(), &[2.0, 4.0, 6.0]);
    }
}
