//! Elementwise and linear-algebra helpers on [`Tensor`].

use super::Tensor;

/// Panel sizes for the blocked matmul: `[TILE_M × TILE_K]` A panels
/// against `[TILE_K × TILE_N]` B panels keep one output panel and one B
/// panel (~64 KB each at f32) resident in cache while A streams.
const TILE_M: usize = 64;
const TILE_K: usize = 64;
const TILE_N: usize = 256;

/// `out += a · b` on row-major slices (`a` is `[M,K]`, `b` is `[K,N]`,
/// `out` is `[M,N]`, pre-initialized with zeros or bias).
///
/// Blocked `TILE_M × TILE_K × TILE_N` with the zero-skip kept on the
/// packed A panel (vector-pruned weight rows skip whole B-row streams).
/// For every output element the K-dimension accumulates in ascending `p`
/// order — exactly the order of the unblocked `ikj` loop — so results are
/// bit-identical to the pre-blocking implementation (EXPERIMENTS.md §Perf).
pub fn matmul_acc_into(out: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A is not [M,K]");
    assert_eq!(b.len(), k * n, "B is not [K,N]");
    assert_eq!(out.len(), m * n, "out is not [M,N]");
    for jb in (0..n).step_by(TILE_N) {
        let jhi = (jb + TILE_N).min(n);
        for ib in (0..m).step_by(TILE_M) {
            let ihi = (ib + TILE_M).min(m);
            for pb in (0..k).step_by(TILE_K) {
                let phi = (pb + TILE_K).min(k);
                for i in ib..ihi {
                    let arow = &a[i * k..(i + 1) * k];
                    let (olo, ohi) = (i * n + jb, i * n + jhi);
                    let orow = &mut out[olo..ohi];
                    for (p, &av) in arow.iter().enumerate().take(phi).skip(pb) {
                        if av == 0.0 {
                            continue; // weight sparsity shortcut
                        }
                        // 8-lane axpy (util::simd): elementwise, so the
                        // ascending-K accumulation order — and the bit
                        // pattern — is unchanged.
                        crate::util::simd::axpy(orow, av, &b[p * n + jb..p * n + jhi]);
                    }
                }
            }
        }
    }
}

/// Matrix multiply: `[M,K] x [K,N] -> [M,N]` (used by the FC layers and the
/// im2col-based fast conv in the performance path).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "inner dims mismatch {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_acc_into(out.data_mut(), a.data(), b.data(), m, k, n);
    out
}

/// im2col: unfold `[C,H,W]` into a `[C*KH*KW, H_out*W_out]` patch matrix so
/// conv becomes a single matmul. Used by the optimized forward path.
pub fn im2col(input: &Tensor, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor {
    assert_eq!(input.ndim(), 3);
    let (c_in, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let h_out = (h + 2 * pad - kh) / stride + 1;
    let w_out = (w + 2 * pad - kw) / stride + 1;
    let mut out = Tensor::zeros(&[c_in * kh * kw, h_out * w_out]);
    im2col_fill(input, kh, kw, stride, pad, out.data_mut());
    out
}

/// [`im2col`] into a caller-owned, pre-zeroed `kdim * cols` buffer — the
/// multithreaded forward recycles the patch matrix (megabytes per conv
/// layer) through the scratch arena instead of re-allocating and
/// page-faulting it on every call.
fn im2col_fill(input: &Tensor, kh: usize, kw: usize, stride: usize, pad: usize, od: &mut [f32]) {
    let (c_in, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let h_out = (h + 2 * pad - kh) / stride + 1;
    let w_out = (w + 2 * pad - kw) / stride + 1;
    let cols = h_out * w_out;
    assert_eq!(od.len(), c_in * kh * kw * cols, "patch buffer size");
    for c in 0..c_in {
        for i in 0..kh {
            for j in 0..kw {
                let row = (c * kh + i) * kw + j;
                for oh in 0..h_out {
                    let ih = (oh * stride + i) as isize - pad as isize;
                    if ih < 0 || ih >= h as isize {
                        continue;
                    }
                    for ow in 0..w_out {
                        let iw = (ow * stride + j) as isize - pad as isize;
                        if iw < 0 || iw >= w as isize {
                            continue;
                        }
                        od[row * cols + oh * w_out + ow] =
                            input.at3(c, ih as usize, iw as usize);
                    }
                }
            }
        }
    }
}

/// Convolution via im2col + matmul. Numerically identical to
/// [`super::conv::conv2d`] (checked in tests) but much faster for the
/// whole-network forward pass.
pub fn conv2d_im2col(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    spec: super::conv::ConvSpec,
) -> Tensor {
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(c_in, input.shape()[0]);
    let h_out = super::conv::out_dim(input.shape()[1], kh, spec);
    let w_out = super::conv::out_dim(input.shape()[2], kw, spec);
    let patches = im2col(input, kh, kw, spec.stride, spec.pad);
    let wmat = weight.clone().reshape(&[k_out, c_in * kh * kw]);
    let mut out = matmul(&wmat, &patches); // [K, H_out*W_out]
    if let Some(b) = bias {
        let od = out.data_mut();
        let cols = h_out * w_out;
        for (k, &bv) in b.iter().enumerate() {
            for x in &mut od[k * cols..(k + 1) * cols] {
                *x += bv;
            }
        }
    }
    out.reshape(&[k_out, h_out, w_out])
}

/// Multithreaded im2col convolution: output channels are split into
/// per-worker chunks on the persistent pool (the patch matrix is shared
/// read-only) — no thread spawns per call. This is the coordinator's
/// fast functional path when PJRT artifacts are not in play. Numerically
/// identical to [`conv2d_im2col`].
pub fn conv2d_im2col_mt(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    spec: super::conv::ConvSpec,
    threads: usize,
) -> Tensor {
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(c_in, input.shape()[0]);
    let threads = threads.max(1).min(k_out);
    if threads == 1 {
        return conv2d_im2col(input, weight, bias, spec);
    }
    let h_out = super::conv::out_dim(input.shape()[1], kh, spec);
    let w_out = super::conv::out_dim(input.shape()[2], kw, spec);
    let cols = h_out * w_out;
    let kdim = c_in * kh * kw;
    // Patch matrix from the scratch arena: the biggest per-call buffer
    // (MBs per layer) allocates once per thread, then recycles.
    let mut patches = crate::util::scratch::take_f32(kdim * cols, 0.0);
    im2col_fill(input, kh, kw, spec.stride, spec.pad, &mut patches);
    let pd: &[f32] = &patches;
    let wd = weight.data();

    let mut out = vec![0.0f32; k_out * cols];
    let chunk = k_out.div_ceil(threads);
    crate::util::par_chunks_mut(&mut out, chunk * cols, |ti, out_chunk| {
        let k_lo = ti * chunk;
        let rows = out_chunk.len() / cols;
        if let Some(b) = bias {
            for (ki, orow) in out_chunk.chunks_mut(cols).enumerate() {
                orow.fill(b[k_lo + ki]);
            }
        }
        // Same blocked panel kernel as `matmul`, on this worker's
        // filter rows against the shared patch matrix.
        matmul_acc_into(
            out_chunk,
            &wd[k_lo * kdim..(k_lo + rows) * kdim],
            pd,
            rows,
            kdim,
            cols,
        );
    });
    crate::util::scratch::recycle_f32(patches);
    Tensor::from_vec(&[k_out, h_out, w_out], out)
}

/// One ABFT column-checksum violation: output column `col` disagrees
/// with the checksum row by `delta`, beyond the rounding `budget` the
/// clean kernel could produce.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AbftFault {
    pub col: usize,
    pub delta: f64,
    pub budget: f64,
}

/// ABFT column checksums over `out = A·B (+ per-row bias)` (ISSUE 10):
/// the checksum row `s = colsum(A)` is carried through the same blocked
/// panel kernel as the payload matmul, and `s·B` must match the column
/// sums of `out` within a rounding budget — any arithmetic or storage
/// upset that lands *after* the checksum row was formed (a MAC-group
/// accumulator flip, a corrupted output word) breaks the identity and is
/// reported with its column. Corruption that predates the checksum (a
/// weight word flipped before `colsum(A)`) is self-consistent here and
/// needs the structural CVF validation / weight scrubbing layers
/// instead.
///
/// `unit_round` is the relative noise floor of one accumulation step
/// (`f32::EPSILON` for the f32 path; precision-coarsened payloads still
/// accumulate in f32, so callers widen it only for headroom). The
/// per-column budget scales with `Σ_p colsum(|A|)_p·|B[p,j]|` — the
/// magnitude actually summed — so dynamic range never produces false
/// positives, while exponent-scale upsets sit orders of magnitude above
/// it. Flips in the lowest mantissa bits hide below the floor; that
/// escape fraction is the coverage the SDC model charges.
pub fn abft_check(
    a: &[f32],
    b: &[f32],
    out: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    unit_round: f64,
) -> Result<(), AbftFault> {
    assert_eq!(a.len(), m * k, "A is not [M,K]");
    assert_eq!(b.len(), k * n, "B is not [K,N]");
    assert_eq!(out.len(), m * n, "out is not [M,N]");
    let mut s = vec![0.0f32; k];
    let mut sabs = vec![0.0f64; k];
    for row in a.chunks_exact(k) {
        for ((sp, ap), &av) in s.iter_mut().zip(sabs.iter_mut()).zip(row) {
            *sp += av;
            *ap += av.abs() as f64;
        }
    }
    // The checksum row rides the exact kernel the payload used.
    let mut want = vec![0.0f32; n];
    matmul_acc_into(&mut want, &s, b, 1, k, n);
    let bias_total: f64 = bias.map_or(0.0, |bv| bv.iter().map(|&x| x as f64).sum());
    let bias_abs: f64 = bias.map_or(0.0, |bv| bv.iter().map(|&x| x.abs() as f64).sum());
    let steps = (k + m + 2) as f64 * unit_round;
    for j in 0..n {
        let mut got = 0.0f64;
        for i in 0..m {
            got += out[i * n + j] as f64;
        }
        let mut scale = bias_abs;
        for (p, &ap) in sabs.iter().enumerate() {
            scale += ap * b[p * n + j].abs() as f64;
        }
        let delta = (got - bias_total - want[j] as f64).abs();
        let budget = steps * (scale + 1.0);
        // A NaN column sum (an exponent flip that overflowed to inf - inf)
        // makes `delta` NaN; that must read as a violation, not slip
        // through a false `>` comparison.
        if delta.is_nan() || delta > budget {
            return Err(AbftFault { col: j, delta, budget });
        }
    }
    Ok(())
}

/// Sum of all elements.
pub fn sum(t: &Tensor) -> f32 {
    t.data().iter().sum()
}

/// Scale all elements in place.
pub fn scale_inplace(t: &mut Tensor, s: f32) {
    for x in t.data_mut() {
        *x *= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::conv::{conv2d, ConvSpec};
    use crate::util::rng::Pcg32;

    fn random_tensor(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n)
            .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
            .collect();
        Tensor::from_vec(shape, data)
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    /// The blocked panel kernel must accumulate every output element in
    /// ascending-K order — bit-identical to the unblocked ikj loop.
    #[test]
    fn blocked_matmul_bit_identical_to_naive() {
        let mut rng = Pcg32::seeded(99);
        for _ in 0..6 {
            let m = rng.range(1, 150);
            let k = rng.range(1, 150);
            let n = rng.range(1, 320);
            let a = random_tensor(&mut rng, &[m, k], 0.5);
            let b = random_tensor(&mut rng, &[k, n], 0.9);
            let (ad, bd) = (a.data(), b.data());
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for p in 0..k {
                    let av = ad[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    for (o, &bv) in want[i * n..(i + 1) * n].iter_mut().zip(&bd[p * n..(p + 1) * n]) {
                        *o += av * bv;
                    }
                }
            }
            let got = matmul(&a, &b);
            assert_eq!(got.data(), &want[..], "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let eye = Tensor::from_vec(&[3, 3], vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(matmul(&a, &eye).data(), a.data());
    }

    /// Property test: im2col+matmul conv equals direct conv on random
    /// shapes, sparsities and paddings.
    #[test]
    fn conv_im2col_matches_direct_randomized() {
        let mut rng = Pcg32::seeded(77);
        for case in 0..40 {
            let c_in = rng.range(1, 5);
            let k_out = rng.range(1, 5);
            let h = rng.range(3, 10);
            let w = rng.range(3, 10);
            let k = [1, 3, 5][rng.range(0, 3)];
            let pad = rng.range(0, k / 2 + 2);
            let stride = rng.range(1, 3);
            if h + 2 * pad < k || w + 2 * pad < k {
                continue;
            }
            let spec = ConvSpec { stride, pad };
            let input = random_tensor(&mut rng, &[c_in, h, w], 0.6);
            let weight = random_tensor(&mut rng, &[k_out, c_in, k, k], 0.5);
            let bias: Vec<f32> = (0..k_out).map(|_| rng.normal()).collect();
            let a = conv2d(&input, &weight, Some(&bias), spec);
            let b = conv2d_im2col(&input, &weight, Some(&bias), spec);
            assert!(
                a.allclose(&b, 1e-4, 1e-4),
                "case {case}: mismatch {} (cin={c_in} k={k} pad={pad} stride={stride})",
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn conv_mt_matches_single_thread() {
        let mut rng = Pcg32::seeded(88);
        for threads in [1usize, 2, 3, 8] {
            let input = random_tensor(&mut rng, &[3, 9, 9], 0.7);
            let weight = random_tensor(&mut rng, &[7, 3, 3, 3], 0.5);
            let bias: Vec<f32> = (0..7).map(|_| rng.normal()).collect();
            let spec = ConvSpec::default();
            let a = conv2d_im2col(&input, &weight, Some(&bias), spec);
            let b = conv2d_im2col_mt(&input, &weight, Some(&bias), spec, threads);
            assert!(
                a.allclose(&b, 1e-6, 1e-6),
                "threads={threads}: {}",
                a.max_abs_diff(&b)
            );
        }
    }

    #[test]
    fn conv_mt_more_threads_than_channels() {
        let mut rng = Pcg32::seeded(89);
        let input = random_tensor(&mut rng, &[2, 5, 5], 1.0);
        let weight = random_tensor(&mut rng, &[2, 2, 3, 3], 1.0);
        let a = conv2d_im2col(&input, &weight, None, ConvSpec::default());
        let b = conv2d_im2col_mt(&input, &weight, None, ConvSpec::default(), 16);
        assert!(a.allclose(&b, 1e-6, 1e-6));
    }

    #[test]
    fn abft_passes_clean_matmuls() {
        let mut rng = Pcg32::seeded(44);
        for _ in 0..10 {
            let m = rng.range(1, 60);
            let k = rng.range(1, 120);
            let n = rng.range(1, 90);
            let a = random_tensor(&mut rng, &[m, k], 0.5);
            let b = random_tensor(&mut rng, &[k, n], 0.9);
            let bias: Vec<f32> = (0..m).map(|_| rng.normal()).collect();
            let mut out = matmul(&a, &b);
            for (i, &bv) in bias.iter().enumerate() {
                for x in &mut out.data_mut()[i * n..(i + 1) * n] {
                    *x += bv;
                }
            }
            abft_check(
                a.data(),
                b.data(),
                out.data(),
                m,
                k,
                n,
                Some(&bias),
                f32::EPSILON as f64,
            )
            .unwrap_or_else(|f| panic!("false positive: m={m} k={k} n={n} {f:?}"));
        }
    }

    #[test]
    fn abft_detects_exponent_scale_upsets() {
        let mut rng = Pcg32::seeded(45);
        let (m, k, n) = (24, 48, 36);
        let a = random_tensor(&mut rng, &[m, k], 0.6);
        let b = random_tensor(&mut rng, &[k, n], 0.9);
        let clean = matmul(&a, &b);
        for _ in 0..20 {
            let mut out = clean.clone();
            let word = rng.range(0, m * n);
            let od = out.data_mut();
            // Flip a high exponent bit — the canonical SRAM upset. Skip
            // near-zero words: nothing of magnitude stored to corrupt.
            if od[word].abs() < 1e-2 {
                continue;
            }
            od[word] = f32::from_bits(od[word].to_bits() ^ (1 << 28));
            let fault = abft_check(a.data(), b.data(), od, m, k, n, None, f32::EPSILON as f64)
                .expect_err("exponent flip must trip the checksum");
            assert_eq!(fault.col, word % n, "fault localized to the flipped column");
            assert!(fault.delta > fault.budget);
        }
        // Sign flip of a nonzero word is also detected.
        let mut out = clean.clone();
        let word = (0..m * n).find(|&i| clean.data()[i].abs() > 0.1).unwrap();
        let v = out.data()[word];
        out.data_mut()[word] = -v;
        assert!(abft_check(a.data(), b.data(), out.data(), m, k, n, None, f32::EPSILON as f64)
            .is_err());
    }

    #[test]
    fn sum_and_scale() {
        let mut t = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        assert_eq!(sum(&t), 6.0);
        scale_inplace(&mut t, 2.0);
        assert_eq!(t.data(), &[2.0, 4.0, 6.0]);
    }
}
