//! Dense tensor substrate: a minimal NCHW `f32` n-d array plus the golden
//! (scalar, obviously-correct) implementations of the CNN operators the
//! simulator and tests check against.
//!
//! The golden ops here are the *functional* reference; the fast path for
//! whole-network forward passes is the PJRT runtime executing the
//! JAX/Pallas-lowered HLO (see [`crate::runtime`]), which is cross-checked
//! against these in integration tests.

pub mod conv;
pub mod ops;

use std::fmt;

/// A dense row-major tensor of `f32` with up to 4 dimensions.
///
/// Shapes follow the paper's convention: activations are `[C, H, W]`
/// (single image; the accelerator processes one feature map at a time) and
/// weights are `[K_out, C_in, KH, KW]`.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    /// Build from shape and data; panics if lengths mismatch.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Shape slice.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw data slice (row-major).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw data vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {shape:?} vs len {}", self.data.len());
        self.shape = shape.to_vec();
        self
    }

    #[inline]
    fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&x, &d)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(x < d, "index {x} out of bounds for dim {i} ({d})");
            off = off * d + x;
        }
        off
    }

    /// Element access by multi-index.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    /// Mutable element access by multi-index.
    #[inline]
    pub fn at_mut(&mut self, idx: &[usize]) -> &mut f32 {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// 3-D accessor for `[C, H, W]` activations (fast path, no Vec index).
    #[inline]
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 3);
        let (hh, ww) = (self.shape[1], self.shape[2]);
        self.data[(c * hh + h) * ww + w]
    }

    /// Mutable 3-D accessor.
    #[inline]
    pub fn at3_mut(&mut self, c: usize, h: usize, w: usize) -> &mut f32 {
        debug_assert_eq!(self.ndim(), 3);
        let (hh, ww) = (self.shape[1], self.shape[2]);
        &mut self.data[(c * hh + h) * ww + w]
    }

    /// 4-D accessor for `[K, C, KH, KW]` weights.
    #[inline]
    pub fn at4(&self, k: usize, c: usize, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 4);
        let (cc, ii, jj) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((k * cc + c) * ii + i) * jj + j]
    }

    /// Mutable 4-D accessor.
    #[inline]
    pub fn at4_mut(&mut self, k: usize, c: usize, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.ndim(), 4);
        let (cc, ii, jj) = (self.shape[1], self.shape[2], self.shape[3]);
        &mut self.data[((k * cc + c) * ii + i) * jj + j]
    }

    /// Count of non-zero elements.
    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Fraction of non-zero elements (element-granularity density).
    pub fn density(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.count_nonzero() as f64 / self.data.len() as f64
        }
    }

    /// Max |a - b| between two same-shape tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// All-close check with absolute + relative tolerance.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Tensor{:?} nnz={}/{} [{}...]",
            self.shape,
            self.count_nonzero(),
            self.len(),
            self.data.iter().take(4).map(|x| format!("{x:.3}")).collect::<Vec<_>>().join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.at3(1, 2, 3), 0.0);
        *t.at3_mut(1, 2, 3) = 5.0;
        assert_eq!(t.at(&[1, 2, 3]), 5.0);
        assert_eq!(t.count_nonzero(), 1);
    }

    #[test]
    fn from_vec_and_reshape() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let t = t.reshape(&[4]);
        assert_eq!(t.at(&[2]), 3.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_len_mismatch_panics() {
        let _ = Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn at4_layout_matches_row_major() {
        let data: Vec<f32> = (0..2 * 3 * 2 * 2).map(|i| i as f32).collect();
        let t = Tensor::from_vec(&[2, 3, 2, 2], data);
        // Element [k=1, c=2, i=1, j=0] is offset ((1*3+2)*2+1)*2+0 = 22.
        assert_eq!(t.at4(1, 2, 1, 0), 22.0);
    }

    #[test]
    fn density_and_allclose() {
        let a = Tensor::from_vec(&[4], vec![0.0, 1.0, 0.0, 2.0]);
        assert!((a.density() - 0.5).abs() < 1e-12);
        let b = Tensor::from_vec(&[4], vec![0.0, 1.0 + 1e-6, 0.0, 2.0]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        assert!(!a.allclose(&b, 1e-9, 0.0));
        assert!(a.max_abs_diff(&b) > 0.0);
    }
}
