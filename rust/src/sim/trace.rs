//! Per-cycle issue trace — regenerates the paper's Table I timing diagram
//! and the Fig 8 dataflow chart for small examples.

use super::index_unit::IssuedPair;

/// One traced cycle of one PE array.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub cycle: u64,
    pub array: usize,
    /// Filter (output channel) the array is serving.
    pub filter: usize,
    /// Input channel.
    pub channel: usize,
    /// Row strip index.
    pub strip: usize,
    pub pair: IssuedPair,
}

/// A bounded cycle trace (records up to `limit` events to keep memory flat
/// on big runs; Table I needs only tens).
#[derive(Debug)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    limit: usize,
    dropped: u64,
}

impl Trace {
    pub fn new(limit: usize) -> Trace {
        Trace {
            events: Vec::new(),
            limit,
            dropped: 0,
        }
    }

    /// Disabled trace (records nothing).
    pub fn disabled() -> Trace {
        Trace::new(0)
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.events.len() < self.limit {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Whether this trace records anything (fast-path check so the
    /// scheduler can skip the functional inner loop on timing-only runs).
    pub fn enabled(&self) -> bool {
        self.limit > 0
    }

    /// Render a Table-I-style timing diagram: one row per field, one column
    /// per cycle, for a single-array single-channel trace. Columns are
    /// labelled like the paper: input columns A.., weight columns WA..WC,
    /// output columns OA.. (X for discarded boundary slots).
    pub fn render_timing_table(&self) -> String {
        fn col_name(i: usize) -> String {
            // 0 -> A, 1 -> B, ... wraps after Z.
            let c = (b'A' + (i % 26) as u8) as char;
            c.to_string()
        }
        let mut input_row = Vec::new();
        let mut weight_row = Vec::new();
        let mut output_row = Vec::new();
        let mut cycle_row = Vec::new();
        for (i, ev) in self.events.iter().enumerate() {
            cycle_row.push(format!("{}", i + 1));
            input_row.push(col_name(ev.pair.input_col));
            weight_row.push(format!("W{}", col_name(ev.pair.weight_col)));
            output_row.push(match ev.pair.output_col {
                Some(o) => format!("O{}", col_name(o)),
                None => "X".to_string(),
            });
        }
        let render = |name: &str, cells: &[String]| {
            let body = cells
                .iter()
                .map(|c| format!("{c:>4}"))
                .collect::<Vec<_>>()
                .join(" |");
            format!("| {name:<6} |{body} |")
        };
        [
            render("Cycle", &cycle_row),
            render("Input", &input_row),
            render("Weight", &weight_row),
            render("Output", &output_row),
        ]
        .join("\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::index_unit::IssuedPair;

    fn ev(cycle: u64, input_col: usize, weight_col: usize, output_col: Option<usize>) -> TraceEvent {
        TraceEvent {
            cycle,
            array: 0,
            filter: 0,
            channel: 0,
            strip: 0,
            pair: IssuedPair {
                input_col,
                weight_col,
                output_col,
            },
        }
    }

    #[test]
    fn limit_and_dropped() {
        let mut t = Trace::new(2);
        t.record(ev(0, 0, 0, Some(1)));
        t.record(ev(1, 0, 1, Some(0)));
        t.record(ev(2, 0, 2, None));
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.dropped(), 1);
    }

    #[test]
    fn timing_table_matches_table1_prefix() {
        // Table I dense cycles 1..3: input A broadcast, weights WA,WB,WC,
        // outputs OB, OA, X.
        let mut t = Trace::new(16);
        t.record(ev(0, 0, 0, Some(1)));
        t.record(ev(1, 0, 1, Some(0)));
        t.record(ev(2, 0, 2, None));
        let table = t.render_timing_table();
        assert!(table.contains("WA"), "{table}");
        assert!(table.contains("OB"), "{table}");
        assert!(table.contains("OA"), "{table}");
        assert!(table.contains("X"), "{table}");
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(ev(0, 0, 0, None));
        assert!(t.events.is_empty());
        assert_eq!(t.dropped(), 1);
    }

    /// Exact drop accounting past the limit: the first `limit` events are
    /// kept, every later record increments `dropped` by exactly one, and
    /// the kept prefix never changes.
    #[test]
    fn drop_accounting_is_exact_past_the_limit() {
        let limit = 5;
        let extra = 13;
        let mut t = Trace::new(limit);
        assert!(t.enabled());
        for i in 0..(limit + extra) as u64 {
            t.record(ev(i, i as usize, 0, Some(i as usize)));
            // dropped = max(0, recorded_so_far - limit), exactly.
            let recorded = i + 1;
            assert_eq!(t.dropped(), recorded.saturating_sub(limit as u64));
            assert_eq!(t.events.len() as u64, recorded.min(limit as u64));
        }
        assert_eq!(t.events.len(), limit);
        assert_eq!(t.dropped(), extra as u64);
        // The kept prefix is the *first* `limit` records, untouched.
        for (i, e) in t.events.iter().enumerate() {
            assert_eq!(e.cycle, i as u64);
            assert_eq!(e.pair.input_col, i);
        }
    }

    /// Events come back in recording order (the export relies on this to
    /// lay issue slots sequentially per array).
    #[test]
    fn event_order_is_recording_order() {
        let mut t = Trace::new(16);
        // Deliberately record out-of-cycle-order events: order of record()
        // calls, not the cycle stamp, defines the sequence.
        t.record(ev(7, 3, 2, None));
        t.record(ev(2, 1, 0, Some(4)));
        t.record(ev(9, 0, 1, Some(0)));
        let cycles: Vec<u64> = t.events.iter().map(|e| e.cycle).collect();
        assert_eq!(cycles, vec![7, 2, 9]);
        let inputs: Vec<usize> = t.events.iter().map(|e| e.pair.input_col).collect();
        assert_eq!(inputs, vec![3, 1, 0]);
    }
}
