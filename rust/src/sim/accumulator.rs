//! Partial-sum accumulation (Fig 3's accumulator + partial-sum SRAM).
//!
//! Partial output columns leave the PE array tagged with their output
//! column (from the index unit) and diagonal offset; the accumulator adds
//! them into the layer's output plane. Because dense and sparse flows tag
//! partials identically, this block is shared — the paper's "same
//! accumulator flow" contribution.

use super::index_unit;
use crate::tensor::Tensor;

/// Accumulates partial output columns into a `[K, H_out, W_out]` plane.
#[derive(Debug)]
pub struct Accumulator {
    out: Tensor,
    /// Number of partial-column accumulations performed.
    pub accumulations: u64,
    /// Partials discarded for falling outside the output plane (boundary
    /// rows OB0/OB6 and X columns).
    pub discarded: u64,
}

impl Accumulator {
    /// Fresh accumulator for a `[K, H_out, W_out]` output.
    pub fn new(k: usize, h_out: usize, w_out: usize) -> Accumulator {
        Accumulator {
            out: Tensor::zeros(&[k, h_out, w_out]),
            accumulations: 0,
            discarded: 0,
        }
    }

    /// Add one cycle's diagonal partial column for filter `k`.
    ///
    /// * `diag` — the `R+C-1` diagonal sums from the PE array;
    /// * `strip_base` — first input row of the strip being processed;
    /// * `out_col` — destination column (`None` = X slot, all discarded);
    /// * `cols`/`pad` — array columns (= kernel height) and padding.
    pub fn add_partial(
        &mut self,
        k: usize,
        diag: &[f32],
        strip_base: usize,
        out_col: Option<usize>,
        cols: usize,
        pad: usize,
    ) {
        let h_out = self.out.shape()[1];
        let Some(col) = out_col else {
            self.discarded += diag.len() as u64;
            return;
        };
        for (d, &v) in diag.iter().enumerate() {
            match index_unit::output_row(strip_base, d, cols, pad, h_out) {
                Some(row) => {
                    *self.out.at3_mut(k, row, col) += v;
                    self.accumulations += 1;
                }
                None => self.discarded += 1,
            }
        }
    }

    /// Finish and take the accumulated output plane.
    pub fn into_output(self) -> Tensor {
        self.out
    }

    /// Peek at the current partial state (tests).
    pub fn output(&self) -> &Tensor {
        &self.out
    }

    /// Mutable access to the partial plane (bias pre-load by the scheduler).
    pub fn output_mut(&mut self) -> &mut Tensor {
        &mut self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::pe_array::diagonal_product;

    /// Accumulating the diagonal products of every (input col, weight col)
    /// pair must reproduce the golden 2-D convolution — the core functional
    /// invariant of the whole dataflow (single channel, single filter).
    #[test]
    fn full_accumulation_equals_conv2d() {
        use crate::tensor::conv::{conv2d, ConvSpec};
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(71);
        for _ in 0..10 {
            let h = rng.range(3, 9);
            let w = rng.range(3, 9);
            let (kh, kw, pad) = (3usize, 3usize, 1usize);
            let input_data: Vec<f32> = (0..h * w).map(|_| rng.normal()).collect();
            let weight_data: Vec<f32> = (0..kh * kw).map(|_| rng.normal()).collect();
            let input = Tensor::from_vec(&[1, h, w], input_data);
            let weight = Tensor::from_vec(&[1, 1, kh, kw], weight_data.clone());
            let spec = ConvSpec { stride: 1, pad };
            let golden = conv2d(&input, &weight, None, spec);

            // Dataflow: single strip covering all rows (R = h).
            let mut acc = Accumulator::new(1, h, w);
            for i in 0..w {
                // input column vector
                let col: Vec<f32> = (0..h).map(|r| input.at3(0, r, i)).collect();
                for j in 0..kw {
                    // weight column = kernel column j (kh taps)
                    let wcol: Vec<f32> = (0..kh).map(|r| weight.at4(0, 0, r, j)).collect();
                    let diag = diagonal_product(&col, &wcol);
                    let out_col = crate::sim::index_unit::output_col(i, j, pad, w);
                    acc.add_partial(0, &diag, 0, out_col, kh, pad);
                }
            }
            let got = acc.into_output();
            assert!(
                golden.allclose(&got, 1e-4, 1e-4),
                "mismatch {} (h={h} w={w})",
                golden.max_abs_diff(&got)
            );
        }
    }

    /// Same invariant with the plane split into strips: boundary diagonals
    /// (OB0/OB6) from adjacent strips must combine to the exact result.
    #[test]
    fn strip_tiling_accumulates_across_boundaries() {
        use crate::tensor::conv::{conv2d, ConvSpec};
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(72);
        let (h, w, r) = (8usize, 6usize, 4usize);
        let (kh, kw, pad) = (3usize, 3usize, 1usize);
        let input = Tensor::from_vec(&[1, h, w], (0..h * w).map(|_| rng.normal()).collect());
        let weight =
            Tensor::from_vec(&[1, 1, kh, kw], (0..kh * kw).map(|_| rng.normal()).collect());
        let spec = ConvSpec { stride: 1, pad };
        let golden = conv2d(&input, &weight, None, spec);

        let mut acc = Accumulator::new(1, h, w);
        for s in 0..h / r {
            let base = s * r;
            for i in 0..w {
                let col: Vec<f32> = (0..r).map(|rr| input.at3(0, base + rr, i)).collect();
                for j in 0..kw {
                    let wcol: Vec<f32> = (0..kh).map(|rr| weight.at4(0, 0, rr, j)).collect();
                    let diag = diagonal_product(&col, &wcol);
                    let out_col = crate::sim::index_unit::output_col(i, j, pad, w);
                    acc.add_partial(0, &diag, base, out_col, kh, pad);
                }
            }
        }
        let got = acc.into_output();
        assert!(
            golden.allclose(&got, 1e-4, 1e-4),
            "mismatch {}",
            golden.max_abs_diff(&got)
        );
    }

    #[test]
    fn x_slots_are_fully_discarded() {
        let mut acc = Accumulator::new(1, 4, 4);
        acc.add_partial(0, &[1.0, 2.0, 3.0], 0, None, 3, 1);
        assert_eq!(acc.discarded, 3);
        assert_eq!(acc.accumulations, 0);
        assert_eq!(acc.output().count_nonzero(), 0);
    }

    #[test]
    fn boundary_rows_discarded_interior_kept() {
        // Strip base 0, R=2, C=3, pad=1, H_out=4: diagonals map to rows
        // d-2+1 = d-1 → d=0 → row -1 (discard), d=1..3 → rows 0..2.
        let mut acc = Accumulator::new(1, 4, 4);
        acc.add_partial(0, &[5.0, 6.0, 7.0, 8.0], 0, Some(2), 3, 1);
        assert_eq!(acc.discarded, 1);
        assert_eq!(acc.accumulations, 3);
        assert_eq!(acc.output().at3(0, 0, 2), 6.0);
        assert_eq!(acc.output().at3(0, 2, 2), 8.0);
    }
}
