//! The VSCNN accelerator model — the paper's system contribution.
//!
//! The simulator is split along the blocks of the paper's Fig 3:
//!
//! * [`config`] — PE-array geometry (`[B, R, C]`), SRAM sizes, clock.
//! * [`pe`] / [`pe_array`] — Fig 4/5: the multiplier+adder PEs, horizontal
//!   input broadcast, vertical weight broadcast, diagonal accumulation.
//! * [`index_unit`] — the vector index system: pairing nonzero input /
//!   weight vectors and computing the output column each pair lands on.
//! * [`accumulator`] — partial-sum accumulation keyed by output index.
//! * [`sram`] / [`dram`] — local buffers, the tiled double-buffered
//!   execution model (`TilePlan` / `stream_tiles`) and external-memory
//!   traffic. Under the default [`config::MemModel::Tiled`] every layer is
//!   charged `max(compute, DRAM transfer)` per SRAM-sized tile;
//!   [`config::MemModel::Ideal`] keeps the pure-compute accounting.
//! * [`scheduler`] — the dense and sparse dataflows of §III / Table I,
//!   including multi-array synchronization (the source of the paper's
//!   92%/85%-of-ideal efficiency).
//! * [`postproc`] — ReLU + zero detection + output vector compression.
//! * [`sdc`] — seeded silent-data-corruption injection, the detection
//!   coverage model, and the protection-cost knobs (ISSUE 10).
//! * [`stats`] — cycle/work/traffic counters behind every figure.
//! * [`trace`] — per-cycle issue trace (regenerates Table I / Fig 8).
//!
//! Two fidelity modes: **functional+timing** (values computed through the
//! dataflow, validated against the golden conv — used by tests and small
//! runs) and **timing-only** (occupancy-derived cycle counts — used for
//! full VGG-16 sweeps; provably identical cycle counts, see
//! `scheduler::tests::functional_and_timing_agree`).

// Delete-or-use policy (ISSUE 3 satellite): everything in the simulator
// model must be exercised by the live timing path, not just unit tests.
#![deny(dead_code)]

pub mod accumulator;
pub mod config;
pub mod dram;
pub mod index_unit;
pub mod mapping;
pub mod pe;
pub mod pe_array;
pub mod postproc;
pub mod scheduler;
pub mod sdc;
pub mod sram;
pub mod stats;
pub mod trace;

pub use config::{MemModel, PeConfig, SimConfig};
pub use scheduler::{simulate_layer, LayerResult, Mode};
pub use stats::{MemBound, SimStats};
