//! The dense and vector-sparse dataflows of §III — the scheduler that maps
//! a conv layer onto the `[B, R, C]` PE arrays and counts every cycle.
//!
//! ## Mapping (from Fig 4/7 and §IV's configuration discussion)
//!
//! * The `H` dimension is tiled into strips of `R` rows; an input vector is
//!   one `R`-row column of one channel within a strip.
//! * The `B` arrays serve `B` different filters (output channels) in
//!   parallel — a *filter group*. Groups are processed sequentially
//!   (`ceil(K / B)` groups).
//! * Within a group each array sweeps channels, strips, then input columns
//!   *independently* (per-array SRAM index pointers); arrays re-synchronize
//!   at the **group boundary**, where the group advances at the pace of its
//!   slowest filter. This group-level load imbalance is the multi-array
//!   **sync loss** separating the design from the ideal vector-sparse
//!   machine — wider groups lose more, which is exactly the paper's 92%
//!   (`[4,14,3]`, 4-filter groups) vs 85% (`[8,7,3]`, 8-filter groups).
//! * Dense mode issues every vector regardless of content; vector-sparse
//!   mode issues only nonzero-vector pairs. Boundary pairs whose output
//!   column falls outside the plane still occupy their slot (Table I `X`),
//!   exactly as the hardware behaves (no look-ahead).
//!
//! The cycle count of the sparse flow is
//! `Σ_groups max_{k ∈ group} Σ_c Σ_strips |nzI(c,s)| · |nzW(k,c)|` plus a
//! small context-switch overhead per active block; dense replaces the two
//! factors by `W` and `KW` (making every filter equal, so dense has no
//! sync loss). The functional mode additionally pushes values through
//! [`PeArray`]/[`Accumulator`] and must reproduce the golden conv exactly.

use super::accumulator::Accumulator;
use super::config::{MemModel, SimConfig};
use super::dram::DramTraffic;
use super::index_unit::{output_col, IssuedPair};
use super::pe_array::diagonal_product_into;
use super::sram::{stream_tiles, SramBuffer, TileDemand, TilePlan};
use super::stats::SimStats;
use super::trace::{Trace, TraceEvent};
use crate::sparse::{VectorActivations, VectorWeights};
use crate::tensor::conv::ConvSpec;
use crate::tensor::Tensor;

/// Dataflow selector: the same hardware, with or without zero skipping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Issue every vector pair (the paper's dense CNN baseline flow).
    Dense,
    /// Skip all-zero input/weight vectors (the paper's contribution).
    VectorSparse,
}

/// Result of simulating one conv layer.
#[derive(Debug)]
pub struct LayerResult {
    pub stats: SimStats,
    /// Cycle count the same layer takes in [`Mode::Dense`] (the speedup
    /// denominator; always computed, it is closed-form).
    pub dense_cycles: u64,
    /// Functional output `[K, H_out, W_out]` (bias added, **pre**-ReLU);
    /// `None` in timing-only runs.
    pub output: Option<Tensor>,
}

/// Simulate one conv layer on the VSCNN accelerator.
///
/// * `input` — `[C, H, W]` activations (post-ReLU of the previous layer);
/// * `weight` — `[K, C, KH, KW]`, `KH` must equal the array column count;
/// * `functional` — also compute output values through the PE dataflow;
/// * `trace` — per-cycle event sink (use [`Trace::disabled`] for speed).
///
/// Only stride 1 is supported (the paper's optimized case; §II-B defers
/// other strides to a remapping layer).
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    // Only the parallel functional path reads the packed payloads; timing
    // and trace runs encode index-only and skip the payload copy.
    let vw = if functional && !trace.enabled() {
        VectorWeights::from_tensor(weight)
    } else {
        VectorWeights::index_only(weight)
    };
    simulate_layer_encoded(input, weight, &vw, bias, cfg, spec, mode, functional, trace)
}

/// [`simulate_layer`] with the weight-side CVF encode supplied by the
/// caller — the execute half of the compile/execute split. `vw` must be the
/// encode of `weight` (value-carrying when `functional` is set without a
/// trace; index-only is enough otherwise); the per-image activation encode
/// still happens here. Statistics and outputs are identical to
/// [`simulate_layer`], which is now a thin wrapper that encodes per call.
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_encoded(
    input: &Tensor,
    weight: &Tensor,
    vw: &VectorWeights,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    let _sp = crate::util::trace_span::span("sim", "simulate_layer");
    crate::util::metrics::add("sim.layers_simulated", 1);
    if trace.enabled() {
        // Issue tracing forces the slow sequential walk; count it so a
        // surprisingly slow run is explainable from the metrics dump.
        crate::util::metrics::add("sim.traced_walks", 1);
    }
    assert_eq!(spec.stride, 1, "VSCNN dataflow models unit stride only");
    assert_eq!(input.ndim(), 3);
    assert_eq!(weight.ndim(), 4);
    let (c_in, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let (k_out, wc, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    assert_eq!(c_in, wc, "channel mismatch");
    assert_eq!(vw.k, k_out, "weight encode does not match the weight tensor");
    assert_eq!(vw.c, wc, "weight encode does not match the weight tensor");
    assert_eq!(
        kh, cfg.pe.cols,
        "kernel height {kh} must equal PE columns {}",
        cfg.pe.cols
    );
    let h_out = crate::tensor::conv::out_dim(h, kh, spec);
    let w_out = crate::tensor::conv::out_dim(w, kw, spec);

    let r = cfg.pe.rows;
    let b = cfg.pe.arrays;
    let want_vals = functional && !trace.enabled();
    let va = if want_vals {
        VectorActivations::from_tensor(input, r)
    } else {
        VectorActivations::index_only(input, r)
    };
    let strips = va.strips;
    let n_groups = k_out.div_ceil(b);

    // Dense reference: every (group, channel, strip) block issues W*KW
    // pairs per array and pays one context switch; under the tiled memory
    // model the dense machine additionally streams its uncompressed data
    // through the same double-buffered hierarchy.
    let dense_blocks = (n_groups * c_in * strips) as u64;
    let dense_cycles = match cfg.mem_model {
        MemModel::Ideal => {
            dense_blocks * (w as u64) * (kw as u64) + dense_blocks * cfg.context_switch_cycles
        }
        MemModel::Tiled => crate::baselines::dense::dense_mem_cycles(cfg, c_in, k_out, h, w, kw),
    };

    let mut stats = SimStats::default();
    let threads = cfg.effective_threads();

    // Dense-mode "virtual" index lists: all columns present.
    let all_input_cols: Vec<u16> = (0..w as u16).collect();
    let all_weight_cols: Vec<u8> = (0..kw as u8).collect();

    // ---- shared precomputes (perf: hoisted out of the group loop;
    // EXPERIMENTS.md §Perf) ------------------------------------------------

    // Per-(c, s) nonzero-input-vector counts.
    let nz_in_per_cs: Vec<u64> = (0..c_in)
        .flat_map(|c| (0..strips).map(move |s| (c, s)))
        .map(|(c, s)| match mode {
            Mode::Dense => w as u64,
            Mode::VectorSparse => va.nz_cols(c, s).len() as u64,
        })
        .collect();
    // Per-channel: Σ_s |nzI| and the number of strips with any work.
    let mut sum_nz_in = vec![0u64; c_in];
    let mut live_strips = vec![0u64; c_in];
    for c in 0..c_in {
        for s in 0..strips {
            let nz = nz_in_per_cs[c * strips + s];
            sum_nz_in[c] += nz;
            live_strips[c] += (nz > 0) as u64;
        }
    }

    // Strip uniformity — the analytic fast path's trigger. Channel `c` is
    // *uniform* when every strip carries the same nonzero-column list
    // (trivially true for single-strip layers and for the dense flow,
    // which issues every column in every strip). Per-strip tallies over
    // identical strips are u64 sums of identical terms, so they collapse
    // to one strip × `strips` bit-identically; `cfg.exact_scheduler`
    // turns the collapse off so tests can pin the equivalence.
    let use_analytic = !cfg.exact_scheduler;
    let uniform: Vec<bool> = match mode {
        Mode::Dense => vec![use_analytic; c_in],
        Mode::VectorSparse => (0..c_in)
            .map(|c| {
                use_analytic && {
                    let first = va.nz_cols(c, 0);
                    (1..strips).all(|s| va.nz_cols(c, s) == first)
                }
            })
            .collect(),
    };

    // --- timing: arrays run independently within a group, sync at the
    // group boundary. work_k = Σ_c [|nzW(k,c)| · Σ_s|nzI(c,s)| + ctx ·
    // live_strips(c)] — channels with no weight vectors cost nothing.
    // Groups are independent between boundary syncs, so they evaluate in
    // parallel; all partials are u64 sums, so the merged totals are
    // identical for every worker count.
    let ctx_cycles = cfg.context_switch_cycles;
    let group_timing = |g: usize| -> (u64, u64, u64, u64) {
        let filters = g * b..((g + 1) * b).min(k_out);
        let n_filters = filters.len() as u64;
        let mut max_work = 0u64;
        let mut max_ctx = 0u64;
        let mut sum_work = 0u64;
        for k in filters {
            let mut wk = 0u64;
            let mut ctx = 0u64;
            for c in 0..c_in {
                let n_wcols = match mode {
                    Mode::Dense => kw as u64,
                    Mode::VectorSparse => vw.nz_cols(k, c).len() as u64,
                };
                if n_wcols == 0 {
                    continue;
                }
                wk += n_wcols * sum_nz_in[c] + ctx_cycles * live_strips[c];
                ctx += ctx_cycles * live_strips[c];
            }
            sum_work += wk;
            if (wk, ctx) > (max_work, max_ctx) {
                max_work = wk;
                max_ctx = ctx;
            }
        }
        (max_work, max_ctx, sum_work, n_filters)
    };
    // Fold one group's (max_work, max_ctx, sum_work, n_filters) into
    // (cycles, overhead, sync_stalls). Every array in the group waits for
    // the slowest filter's total work (pairs + context switches); arrays
    // with no filter in a ragged last group stall the whole group — see
    // `sync_stall_pinned_for_two_filter_group`.
    let fold_group = |acc: &mut (u64, u64, u64), t: (u64, u64, u64, u64)| {
        let (max_work, max_ctx, sum_work, n_filters) = t;
        acc.0 += max_work;
        acc.1 += max_ctx;
        acc.2 += n_filters * max_work - sum_work + (b as u64 - n_filters) * max_work;
    };
    let timing_workers = if n_groups * b * c_in >= (1 << 14) {
        threads
    } else {
        1
    };
    let mut timing = (0u64, 0u64, 0u64);
    // Per-group slowest-filter work, kept for the tiled model: when one
    // tile covers the whole group, its compute demand *is* this number
    // (see the analytic fast path below).
    let mut group_max: Vec<u64> = Vec::with_capacity(n_groups);
    for (p, maxes) in crate::util::par_chunk_map(n_groups, timing_workers, |groups| {
        let mut acc = (0u64, 0u64, 0u64);
        let mut maxes = Vec::with_capacity(groups.len());
        for g in groups {
            let t = group_timing(g);
            maxes.push(t.0);
            fold_group(&mut acc, t);
        }
        (acc, maxes)
    }) {
        timing.0 += p.0;
        timing.1 += p.1;
        timing.2 += p.2;
        group_max.extend(maxes);
    }
    stats.cycles += timing.0;
    stats.overhead_cycles += timing.1;
    stats.sync_stall_slots += timing.2;

    // --- per-pair accounting: group-independent, computed once per
    // channel — channels are independent, so they too fan out across
    // workers (u64 partial sums ⇒ deterministic totals). Tally order:
    // (issued, macs, skipped_input, skipped_weight, boundary).
    let pair_tally = |c: usize| -> (u64, u64, u64, u64, u64) {
        // Σ over all filters of this channel's nonzero weight vectors, and
        // how many filters carry each kernel column j.
        let mut sum_w_all = 0u64;
        let mut filters_with_j = vec![0u64; kw];
        match mode {
            Mode::Dense => {
                sum_w_all = (k_out * kw) as u64;
                filters_with_j.fill(k_out as u64);
            }
            Mode::VectorSparse => {
                for k in 0..k_out {
                    for &j in vw.nz_cols(k, c) {
                        sum_w_all += 1;
                        filters_with_j[j as usize] += 1;
                    }
                }
            }
        }

        let mut t = (0u64, 0u64, 0u64, 0u64, 0u64);
        let skipped_w_per_nz_input = (k_out * kw) as u64 - sum_w_all;
        // One strip's contribution, `mult` times over (all tallies are
        // u64 sums, so `mult` identical strips fold to one multiply —
        // bit-identical to the per-strip walk).
        let mut strip_tally = |icols: &[u16], mult: u64| {
            if icols.is_empty() {
                if mode == Mode::VectorSparse {
                    t.2 += mult * (w * k_out * kw) as u64;
                }
                return;
            }
            if mode == Mode::VectorSparse {
                t.2 += mult * (w as u64 - icols.len() as u64) * (k_out * kw) as u64;
                t.3 += mult * icols.len() as u64 * skipped_w_per_nz_input;
            }

            let issued: u64 = icols.len() as u64 * sum_w_all;
            t.0 += mult * issued;
            t.1 += mult * issued * (r as u64) * (kh as u64);

            // Boundary (X) pairs: output col i - j + pad outside the
            // plane. Counted per kernel column once, weighted by how many
            // filters issue that column.
            for (j, &nf) in filters_with_j.iter().enumerate() {
                if nf == 0 {
                    continue;
                }
                let lo = j as i64 - spec.pad as i64; // i < lo invalid
                let hi = w_out as i64 + j as i64 - spec.pad as i64; // i >= hi invalid
                let below = icols.partition_point(|&i| (i as i64) < lo) as u64;
                let above =
                    icols.len() as u64 - icols.partition_point(|&i| (i as i64) < hi) as u64;
                t.4 += mult * nf * (below + above);
            }
        };
        if uniform[c] {
            // Analytic fast path: every strip is the same strip.
            let icols: &[u16] = match mode {
                Mode::Dense => &all_input_cols,
                Mode::VectorSparse => va.nz_cols(c, 0),
            };
            strip_tally(icols, strips as u64);
        } else {
            for s in 0..strips {
                let icols: &[u16] = match mode {
                    Mode::Dense => &all_input_cols,
                    Mode::VectorSparse => va.nz_cols(c, s),
                };
                strip_tally(icols, 1);
            }
        }
        t
    };
    let tally_workers = if c_in * (k_out * kw + strips * 4) >= (1 << 14) {
        threads
    } else {
        1
    };
    let mut tally = (0u64, 0u64, 0u64, 0u64, 0u64);
    for p in crate::util::par_chunk_map(c_in, tally_workers, |channels| {
        let mut acc = (0u64, 0u64, 0u64, 0u64, 0u64);
        for c in channels {
            add5(&mut acc, pair_tally(c));
        }
        acc
    }) {
        add5(&mut tally, p);
    }
    stats.issued_pairs += tally.0;
    stats.macs += tally.1;
    stats.skipped_input += tally.2;
    stats.skipped_weight += tally.3;
    stats.boundary_pairs += tally.4;

    // --- functional + trace (values through the PE dataflow) ------------
    let mut output: Option<Tensor> = None;
    if want_vals {
        // Fast path: per-filter output planes are disjoint, so filters fan
        // out across workers; the packed CVF payloads make the inner loop
        // read contiguous slices with zero heap allocation.
        output = Some(functional_forward(
            input,
            weight,
            bias,
            &va,
            vw,
            mode,
            spec,
            FuncDims {
                r,
                kh,
                kw,
                k_out,
                c_in,
                strips,
                h,
                h_out,
                w_out,
            },
            threads,
        ));
    } else if functional || trace.enabled() {
        // Trace path: sequential so cycle events interleave exactly as the
        // single-issue hardware would; only used for Table-I-sized runs.
        let mut acc = functional.then(|| {
            let mut a = Accumulator::new(k_out, h_out, w_out);
            if let Some(bias) = bias {
                for (k, &bv) in bias.iter().enumerate() {
                    for row in 0..h_out {
                        for col in 0..w_out {
                            *a.output_mut().at3_mut(k, row, col) = bv;
                        }
                    }
                }
            }
            a
        });
        let mut col = vec![0.0f32; r];
        let mut wcol = vec![0.0f32; kh];
        let mut diag = vec![0.0f32; r + kh - 1];
        for g in 0..n_groups {
            let filters: Vec<usize> = (g * b..((g + 1) * b).min(k_out)).collect();
            for c in 0..c_in {
                let wcols: Vec<&[u8]> = filters
                    .iter()
                    .map(|&k| match mode {
                        Mode::Dense => &all_weight_cols[..],
                        Mode::VectorSparse => vw.nz_cols(k, c),
                    })
                    .collect();
                for s in 0..strips {
                    let icols: &[u16] = match mode {
                        Mode::Dense => &all_input_cols,
                        Mode::VectorSparse => va.nz_cols(c, s),
                    };
                    let base = s * r;
                    let rows_here = ((s + 1) * r).min(h) - base;
                    for (pos, &i) in icols.iter().enumerate() {
                        // Input column vector (zero-padded to R for ragged
                        // last strips).
                        col.fill(0.0);
                        for (rr, cv) in col.iter_mut().enumerate().take(rows_here) {
                            *cv = input.at3(c, base + rr, i as usize);
                        }
                        for (ai, &k) in filters.iter().enumerate() {
                            for &j in wcols[ai] {
                                let oc = output_col(i as usize, j as usize, spec.pad, w_out);
                                trace.record(TraceEvent {
                                    cycle: pos as u64,
                                    array: ai,
                                    filter: k,
                                    channel: c,
                                    strip: s,
                                    pair: IssuedPair {
                                        input_col: i as usize,
                                        weight_col: j as usize,
                                        output_col: oc,
                                    },
                                });
                                if let Some(acc) = acc.as_mut() {
                                    for (rr, wv) in wcol.iter_mut().enumerate() {
                                        *wv = weight.at4(k, c, rr, j as usize);
                                    }
                                    diagonal_product_into(&col, &wcol, &mut diag);
                                    acc.add_partial(k, &diag, base, oc, kh, spec.pad);
                                }
                            }
                        }
                    }
                }
            }
        }
        output = acc.map(|a| a.into_output());
    }

    // --- DRAM traffic -------------------------------------------------
    let bpe = cfg.sram.bytes_per_elem;
    let (in_elems, in_vecs, w_elems, w_vecs) = match mode {
        Mode::Dense => (
            c_in * h * w,
            0usize,
            k_out * c_in * kh * kw,
            0usize,
        ),
        Mode::VectorSparse => (
            va.sram_elems(),
            va.nonzero_vectors(),
            vw.sram_elems(),
            vw.nonzero_vectors(),
        ),
    };
    // Inputs are re-read once per filter group unless the input buffer
    // holds the layer's (compressed) activations entirely. Under fused
    // strip execution the producing layer left them resident in input
    // SRAM, so they never touch DRAM at all.
    let input_rounds = if cfg.fused_input_resident {
        0
    } else if cfg.sram.input_bytes >= in_elems * bpe {
        1
    } else {
        n_groups
    } as u64;
    // SRAM residency peaks (Fig 3's buffers): the input buffer holds the
    // layer's compressed activations (or the largest strip working set
    // when streaming), the weight buffer one filter group, the psum buffer
    // one strip of partial output columns per array.
    stats.sram_input_peak = ((in_elems * bpe) as u64).min(cfg.sram.input_bytes as u64);
    stats.sram_weight_peak = ((w_elems * bpe) as u64 / n_groups.max(1) as u64)
        .max((b * kh * kw * bpe) as u64);
    stats.sram_psum_peak = (b * (r + kh - 1) * w_out * bpe) as u64;
    stats.dram = DramTraffic {
        input_read: (in_elems * bpe) as u64 * input_rounds,
        weight_read: (w_elems * bpe) as u64,
        // Output traffic is added by the coordinator after post-processing
        // (it depends on the *output* sparsity).
        output_write: 0,
        index_bytes: ((in_vecs as u64 * input_rounds) + w_vecs as u64) * 2,
    };

    // --- tiled memory model ---------------------------------------------
    // Under MemModel::Ideal the cycle count above *is* the result (pure
    // compute, pinned bit-for-bit by tests/memory_model.rs). Under Tiled
    // the layer re-times as SRAM-sized tiles streamed through the
    // double-buffered hierarchy: each tile costs max(compute, transfer),
    // the first fill is a serial prologue, and arrays re-sync at every
    // tile boundary (buffer swap) — so tiled compute >= the group-synced
    // ideal count, and total cycles >= max(compute, transfer) always.
    stats.compute_cycles = stats.cycles;
    if cfg.mem_model == MemModel::Tiled {
        let demands = match mode {
            Mode::Dense => crate::baselines::dense::dense_tile_demands(cfg, c_in, k_out, h, w, kw),
            Mode::VectorSparse => {
                let idx = 2u64; // index bytes per nonzero vector
                let bpe64 = bpe as u64;
                // Per-strip compressed input bytes, with a raw-format
                // escape per (channel, strip): the DMA stores a vector
                // group uncompressed when CVF doesn't pay (index overhead
                // at near-full density), so sparse traffic never exceeds
                // the dense machine's.
                let strip_in_bytes: Vec<u64> = (0..strips)
                    .map(|s| {
                        if cfg.fused_input_resident {
                            // Fused strip execution: every strip is
                            // already resident, so all three demand
                            // paths below see zero input transfer.
                            return 0;
                        }
                        let rows = (((s + 1) * r).min(h) - s * r) as u64;
                        let raw = rows * w as u64 * bpe64;
                        (0..c_in)
                            .map(|c| {
                                (nz_in_per_cs[c * strips + s] * (r as u64 * bpe64 + idx)).min(raw)
                            })
                            .sum()
                    })
                    .collect();
                // Per-group compressed weight bytes, same escape per (k, c).
                let group_w_bytes: Vec<u64> = (0..n_groups)
                    .map(|g| {
                        let mut bytes = 0u64;
                        for k in g * b..((g + 1) * b).min(k_out) {
                            for c in 0..c_in {
                                let cvf =
                                    vw.nz_cols(k, c).len() as u64 * (kh as u64 * bpe64 + idx);
                                bytes += cvf.min((kh * kw * bpe) as u64);
                            }
                        }
                        bytes
                    })
                    .collect();
                let in_total: u64 = strip_in_bytes.iter().sum();
                let input_resident = cfg.sram.input_bytes as u64 >= in_total;
                let max_group = group_w_bytes.iter().copied().max().unwrap_or(0) as usize;
                let plan =
                    TilePlan::new(&cfg.sram, &cfg.pe, c_in, h, w, w_out, k_out, max_group);

                let mut demands = Vec::with_capacity(plan.total_tiles());
                if use_analytic && plan.tiles_per_group == 1 {
                    // Analytic fast path #1 — one tile per group (the whole
                    // layer's strips fit the input-buffer half, the common
                    // case at small/medium resolutions): the tile covers
                    // every strip, so its compute demand is exactly the
                    // group-boundary max the timing pass already computed.
                    // No per-strip walk, O(groups) total.
                    for (g, &compute) in group_max.iter().enumerate() {
                        demands.push(TileDemand {
                            compute,
                            input_bytes: if g == 0 || !input_resident { in_total } else { 0 },
                            weight_bytes: group_w_bytes[g],
                        });
                    }
                } else if use_analytic && uniform.iter().all(|&u| u) {
                    // Analytic fast path #2 — every channel strip-uniform:
                    // a filter's work over any strip range is (range
                    // length) × its per-strip work, so the slowest filter
                    // of a tile is tile_len × the group's per-strip max
                    // (u64 distributivity — bit-identical to the walk).
                    let nz0: Vec<u64> = (0..c_in).map(|c| nz_in_per_cs[c * strips]).collect();
                    for g in 0..n_groups {
                        let mut per_strip_max = 0u64;
                        for k in g * b..((g + 1) * b).min(k_out) {
                            let mut wk = 0u64;
                            for (c, &nz) in nz0.iter().enumerate() {
                                let n_wcols = vw.nz_cols(k, c).len() as u64;
                                if n_wcols == 0 {
                                    continue;
                                }
                                wk += n_wcols * nz + ctx_cycles * u64::from(nz > 0);
                            }
                            per_strip_max = per_strip_max.max(wk);
                        }
                        for t in 0..plan.tiles_per_group {
                            let srange = plan.tile_strips(t);
                            let len = (srange.end - srange.start) as u64;
                            let input_bytes: u64 = if g == 0 || !input_resident {
                                srange.map(|s| strip_in_bytes[s]).sum()
                            } else {
                                0
                            };
                            let weight_bytes = if t == 0 || !plan.weight_group_fits {
                                group_w_bytes[g]
                            } else {
                                0
                            };
                            demands.push(TileDemand {
                                compute: len * per_strip_max,
                                input_bytes,
                                weight_bytes,
                            });
                        }
                    }
                } else {
                    // Exact per-strip walk, with prefix sums over strips
                    // per channel: Σ nzI and live strips of any strip
                    // range in O(1).
                    let stride = strips + 1;
                    let mut pref_nz = vec![0u64; c_in * stride];
                    let mut pref_live = vec![0u64; c_in * stride];
                    for c in 0..c_in {
                        for s in 0..strips {
                            let nz = nz_in_per_cs[c * strips + s];
                            pref_nz[c * stride + s + 1] = pref_nz[c * stride + s] + nz;
                            pref_live[c * stride + s + 1] =
                                pref_live[c * stride + s] + u64::from(nz > 0);
                        }
                    }
                    for g in 0..n_groups {
                        for t in 0..plan.tiles_per_group {
                            let srange = plan.tile_strips(t);
                            // Slowest filter in the group over the tile's
                            // strips.
                            let mut compute = 0u64;
                            for k in g * b..((g + 1) * b).min(k_out) {
                                let mut wk = 0u64;
                                for c in 0..c_in {
                                    let n_wcols = vw.nz_cols(k, c).len() as u64;
                                    if n_wcols == 0 {
                                        continue;
                                    }
                                    let base = c * stride;
                                    let nz =
                                        pref_nz[base + srange.end] - pref_nz[base + srange.start];
                                    let live = pref_live[base + srange.end]
                                        - pref_live[base + srange.start];
                                    wk += n_wcols * nz + ctx_cycles * live;
                                }
                                compute = compute.max(wk);
                            }
                            let input_bytes: u64 = if g == 0 || !input_resident {
                                srange.map(|s| strip_in_bytes[s]).sum()
                            } else {
                                0
                            };
                            let weight_bytes = if t == 0 || !plan.weight_group_fits {
                                group_w_bytes[g]
                            } else {
                                0
                            };
                            demands.push(TileDemand {
                                compute,
                                input_bytes,
                                weight_bytes,
                            });
                        }
                    }
                }
                demands
            }
        };
        let timing = stream_tiles(&cfg.sram, cfg.dram_bytes_per_cycle, &demands);
        // Psum capacity: one strip of partial output columns per array
        // must stay resident (Fig 3's psum buffer).
        let mut psum = SramBuffer::new("psum", cfg.sram.psum_bytes);
        let psum_ok = psum.fill(b * (r + kh - 1) * w_out * bpe);
        stats.cycles = timing.cycles;
        stats.compute_cycles = timing.compute_cycles;
        stats.transfer_cycles = timing.transfer_cycles;
        stats.fill_cycles = timing.fill_cycles;
        stats.tiles = timing.tiles;
        stats.sram_overflows = timing.overflows + u64::from(!psum_ok);
    }

    LayerResult {
        stats,
        dense_cycles,
        output,
    }
}

/// Dimensions threaded into [`functional_forward`] (one bundle instead of
/// nine loose arguments).
struct FuncDims {
    r: usize,
    kh: usize,
    kw: usize,
    k_out: usize,
    c_in: usize,
    strips: usize,
    h: usize,
    h_out: usize,
    w_out: usize,
}

/// Element-wise 5-tuple accumulate for the per-channel pair tallies.
fn add5(a: &mut (u64, u64, u64, u64, u64), b: (u64, u64, u64, u64, u64)) {
    a.0 += b.0;
    a.1 += b.1;
    a.2 += b.2;
    a.3 += b.3;
    a.4 += b.4;
}

/// The valid diagonal window of one strip: diagonal element `d` lands on
/// output row `strip_base + d - (kh - 1) + pad`, which is monotone in
/// `d`, so the rows inside `[0, h_out)` form one contiguous run. Returns
/// `(d_lo, d_hi, row_lo)` with `d_lo <= d_hi`: diagonal elements
/// `[d_lo, d_hi)` accumulate into rows `[row_lo, row_lo + d_hi - d_lo)`.
/// Exactly the `Some` set of `index_unit::output_row` over
/// `0..diag_len`, precomputed once per strip so the MAC accumulation is
/// a branch-free contiguous add.
#[inline]
fn diag_clip(
    strip_base: usize,
    diag_len: usize,
    kh: usize,
    pad: usize,
    h_out: usize,
) -> (usize, usize, usize) {
    let shift = strip_base as i64 + pad as i64 - (kh as i64 - 1);
    let d_lo = (-shift).max(0) as usize;
    let d_hi = (h_out as i64 - shift).min(diag_len as i64).max(d_lo as i64) as usize;
    let row_lo = if d_hi > d_lo {
        (shift + d_lo as i64) as usize
    } else {
        0
    };
    (d_lo, d_hi, row_lo)
}

/// The functional dataflow, parallel and allocation-free: filters split
/// into per-worker chunks on the persistent pool (their `[H_out, W_out]`
/// output planes are disjoint), each worker borrowing its scratch from
/// the thread's [`crate::util::scratch`] arena. Each filter accumulates
/// into a **transposed** (`[W_out, H_out]`) scratch plane, so one issued
/// pair's partial column is a contiguous, branch-free add of the clipped
/// diagonal run ([`diag_clip`]); the plane is un-transposed once at the
/// end. Per filter the (channel, strip, input column, weight column,
/// diagonal) order matches the sequential trace path exactly, and a
/// transpose only permutes independently-accumulated sums — so outputs
/// are bit-identical for every worker count and to the pre-SoA loop.
#[allow(clippy::too_many_arguments)]
fn functional_forward(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    va: &VectorActivations,
    vw: &VectorWeights,
    mode: Mode,
    spec: ConvSpec,
    d: FuncDims,
    threads: usize,
) -> Tensor {
    let FuncDims {
        r,
        kh,
        kw,
        k_out,
        c_in,
        strips,
        h,
        h_out,
        w_out,
    } = d;
    let plane = h_out * w_out;
    let w_in = input.shape()[2];
    let diag_len = r + kh - 1;
    let mut out = vec![0.0f32; k_out * plane];
    let workers = threads.max(1).min(k_out.max(1));
    let chunk = k_out.div_ceil(workers).max(1);
    crate::util::par_chunks_mut(&mut out, chunk * plane, |ti, out_chunk| {
        let k_lo = ti * chunk;
        // Per-worker scratch from the thread's arena — the only buffers
        // the hot loop touches; nothing allocates past the worker's
        // first-ever layer.
        let mut icol = crate::util::scratch::take_f32(r, 0.0);
        let mut wcol = crate::util::scratch::take_f32(kh, 0.0);
        let mut diag = crate::util::scratch::take_f32(diag_len, 0.0);
        let mut tplane = crate::util::scratch::take_f32(plane, 0.0);
        for (ki, kplane) in out_chunk.chunks_mut(plane).enumerate() {
            let k = k_lo + ki;
            tplane.fill(bias.map_or(0.0, |bs| bs[k]));
            for c in 0..c_in {
                match mode {
                    Mode::VectorSparse => {
                        let wcols = vw.nz_cols(k, c);
                        if wcols.is_empty() {
                            continue;
                        }
                        let wvals = vw.nz_vals(k, c);
                        for s in 0..strips {
                            let icols = va.nz_cols(c, s);
                            if icols.is_empty() {
                                continue;
                            }
                            let (soa, n) = va.nz_group_soa(c, s);
                            let (d_lo, d_hi, row_lo) =
                                diag_clip(s * r, diag_len, kh, spec.pad, h_out);
                            for (pos, &i) in icols.iter().enumerate() {
                                // Gather this vector from the SoA planes.
                                let mut idx = pos;
                                for iv in icol.iter_mut() {
                                    *iv = soa[idx];
                                    idx += n;
                                }
                                for (wpos, &j) in wcols.iter().enumerate() {
                                    let Some(oc) =
                                        output_col(i as usize, j as usize, spec.pad, w_out)
                                    else {
                                        continue; // boundary X slot
                                    };
                                    let wv = &wvals[wpos * kh..(wpos + 1) * kh];
                                    diagonal_product_into(&icol, wv, &mut diag);
                                    let dst = oc * h_out + row_lo;
                                    crate::util::simd::add_assign(
                                        &mut tplane[dst..dst + (d_hi - d_lo)],
                                        &diag[d_lo..d_hi],
                                    );
                                }
                            }
                        }
                    }
                    Mode::Dense => {
                        for s in 0..strips {
                            let base = s * r;
                            let rows_here = ((s + 1) * r).min(h) - base;
                            let (d_lo, d_hi, row_lo) =
                                diag_clip(base, diag_len, kh, spec.pad, h_out);
                            for i in 0..w_in {
                                icol.fill(0.0);
                                for (rr, cv) in icol.iter_mut().enumerate().take(rows_here) {
                                    *cv = input.at3(c, base + rr, i);
                                }
                                for j in 0..kw {
                                    let Some(oc) = output_col(i, j, spec.pad, w_out) else {
                                        continue;
                                    };
                                    for (rr, wv) in wcol.iter_mut().enumerate() {
                                        *wv = weight.at4(k, c, rr, j);
                                    }
                                    diagonal_product_into(&icol, &wcol, &mut diag);
                                    let dst = oc * h_out + row_lo;
                                    crate::util::simd::add_assign(
                                        &mut tplane[dst..dst + (d_hi - d_lo)],
                                        &diag[d_lo..d_hi],
                                    );
                                }
                            }
                        }
                    }
                }
            }
            // Un-transpose the accumulated [W_out, H_out] plane into the
            // row-major output chunk (a pure permutation of finished
            // sums — no reordering of additions).
            for (row, out_row) in kplane.chunks_exact_mut(w_out).enumerate() {
                for (col, o) in out_row.iter_mut().enumerate() {
                    *o = tplane[col * h_out + row];
                }
            }
        }
        crate::util::scratch::recycle_f32(icol);
        crate::util::scratch::recycle_f32(wcol);
        crate::util::scratch::recycle_f32(diag);
        crate::util::scratch::recycle_f32(tplane);
    });
    Tensor::from_vec(&[k_out, h_out, w_out], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SimConfig;
    use crate::tensor::conv::{conv2d, ConvSpec};
    use crate::util::rng::Pcg32;

    // Hand-computed expectations in this module pin the *compute* cycle
    // model, so they run under the ideal memory model; the tiled model's
    // own invariants are covered below and in tests/memory_model.rs.
    fn small_cfg(arrays: usize, rows: usize) -> SimConfig {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = arrays;
        cfg.pe.rows = rows;
        cfg.context_switch_cycles = 0;
        cfg.mem_model = MemModel::Ideal;
        cfg
    }

    fn random_sparse(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(
            shape,
            (0..n)
                .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
                .collect(),
        )
    }

    /// The paper's worked example (Fig 6/7, Table I): 5x5 input, pad 1,
    /// 3x3 kernel, 15 PEs (R=5). Dense = 15 cycles, sparse = 8 cycles
    /// (input column B and weight column WC all-zero), saving 47%.
    #[test]
    fn table1_cycle_counts() {
        let cfg = small_cfg(1, 5);
        let spec = ConvSpec { stride: 1, pad: 1 };
        // Build the example: column B (index 1) of the input is zero and
        // kernel column WC (index 2) is zero.
        let mut rng = Pcg32::seeded(2);
        let mut input = Tensor::zeros(&[1, 5, 5]);
        for r in 0..5 {
            for c in [0usize, 2, 3, 4] {
                *input.at3_mut(0, r, c) = rng.f32_range(0.5, 1.0);
            }
        }
        let mut weight = Tensor::zeros(&[1, 1, 3, 3]);
        for i in 0..3 {
            for j in 0..2 {
                *weight.at4_mut(0, 0, i, j) = rng.f32_range(0.5, 1.0);
            }
        }

        let mut tr = Trace::disabled();
        let dense = simulate_layer(
            &input, &weight, None, &cfg, spec, Mode::Dense, false, &mut tr,
        );
        assert_eq!(dense.stats.cycles, 15);
        assert_eq!(dense.dense_cycles, 15);

        let sparse = simulate_layer(
            &input, &weight, None, &cfg, spec, Mode::VectorSparse, false, &mut tr,
        );
        assert_eq!(sparse.stats.cycles, 8);
        // Saving 47% (paper §III).
        let saving = 1.0 - sparse.stats.cycles as f64 / dense.stats.cycles as f64;
        assert!((saving - 0.4667).abs() < 0.01, "saving {saving}");
        // Skip accounting must close the books: issued + skipped = dense.
        assert_eq!(
            sparse.stats.issued_pairs + sparse.stats.skipped_pairs(),
            15
        );
        // Table I sparse flow has exactly one X slot (E × WA).
        assert_eq!(sparse.stats.boundary_pairs, 1);
    }

    /// Functional invariant: the sparse dataflow output equals the golden
    /// conv (zero vectors contribute nothing), dense likewise.
    #[test]
    fn functional_matches_conv2d() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..8 {
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 6);
            let h = rng.range(4, 12);
            let w = rng.range(4, 12);
            let spec = ConvSpec { stride: 1, pad: 1 };
            let cfg = small_cfg(rng.range(1, 4), rng.range(2, 6));
            let input = random_sparse(&mut rng, &[c_in, h, w], 0.5);
            let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], 0.4);
            let bias: Vec<f32> = (0..k_out).map(|_| rng.normal()).collect();
            let golden = conv2d(&input, &weight, Some(&bias), spec);

            let mut tr = Trace::disabled();
            for mode in [Mode::Dense, Mode::VectorSparse] {
                let res = simulate_layer(
                    &input,
                    &weight,
                    Some(&bias),
                    &cfg,
                    spec,
                    mode,
                    true,
                    &mut tr,
                );
                let out = res.output.unwrap();
                assert!(
                    golden.allclose(&out, 1e-3, 1e-3),
                    "mode {mode:?}: diff {}",
                    golden.max_abs_diff(&out)
                );
            }
        }
    }

    /// The parallel functional path must be bit-identical across worker
    /// counts AND to the sequential (trace-enabled) dataflow — the perf
    /// refactor changes no semantics.
    #[test]
    fn functional_output_identical_across_thread_counts() {
        let mut rng = Pcg32::seeded(77);
        let input = random_sparse(&mut rng, &[3, 10, 9], 0.5);
        let weight = random_sparse(&mut rng, &[5, 3, 3, 3], 0.4);
        let bias: Vec<f32> = (0..5).map(|_| rng.normal()).collect();
        let spec = ConvSpec::default();
        let mut cfg = small_cfg(2, 4);
        let mut outs: Vec<Tensor> = Vec::new();
        for threads in [1usize, 2, 7] {
            cfg.threads = threads;
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                Some(&bias),
                &cfg,
                spec,
                Mode::VectorSparse,
                true,
                &mut tr,
            );
            outs.push(res.output.unwrap());
        }
        assert_eq!(outs[0].data(), outs[1].data());
        assert_eq!(outs[0].data(), outs[2].data());

        // Sequential dataflow (trace enabled forces the legacy loop).
        let mut tr = Trace::new(4);
        let seq = simulate_layer(
            &input,
            &weight,
            Some(&bias),
            &cfg,
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        assert_eq!(seq.output.unwrap().data(), outs[0].data());
    }

    /// Sparse cycles never exceed dense cycles, and equal them for fully
    /// dense data.
    #[test]
    fn sparse_never_slower() {
        let mut rng = Pcg32::seeded(10);
        let cfg = small_cfg(2, 4);
        let spec = ConvSpec::default();
        for density in [1.0f32, 0.8, 0.4, 0.1] {
            let input = random_sparse(&mut rng, &[2, 8, 8], density);
            let weight = random_sparse(&mut rng, &[4, 2, 3, 3], density);
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input, &weight, None, &cfg, spec, Mode::VectorSparse, false, &mut tr,
            );
            assert!(
                res.stats.cycles <= res.dense_cycles,
                "density {density}: {} > {}",
                res.stats.cycles,
                res.dense_cycles
            );
            if density == 1.0 {
                assert_eq!(res.stats.cycles, res.dense_cycles);
                assert_eq!(res.stats.skipped_pairs(), 0);
            }
        }
    }

    /// Smaller R (more, shorter vectors) can only expose more zero vectors:
    /// cycles(R=2) <= cycles(R=8) on the same data — the paper's reason
    /// [8,7,3] beats [4,14,3].
    #[test]
    fn smaller_vectors_skip_more() {
        let mut rng = Pcg32::seeded(11);
        let input = random_sparse(&mut rng, &[2, 16, 10], 0.3);
        let weight = random_sparse(&mut rng, &[2, 2, 3, 3], 0.5);
        let spec = ConvSpec::default();
        let mut tr = Trace::disabled();
        let big = simulate_layer(
            &input,
            &weight,
            None,
            &small_cfg(1, 8),
            spec,
            Mode::VectorSparse,
            false,
            &mut tr,
        );
        let small = simulate_layer(
            &input,
            &weight,
            None,
            &small_cfg(1, 2),
            spec,
            Mode::VectorSparse,
            false,
            &mut tr,
        );
        // Normalize: cycles scale with strip count × vector length; compare
        // issued pairs per dense pair instead.
        let frac_big = big.stats.cycles as f64 / big.dense_cycles as f64;
        let frac_small = small.stats.cycles as f64 / small.dense_cycles as f64;
        assert!(
            frac_small <= frac_big + 1e-9,
            "small {frac_small} vs big {frac_big}"
        );
    }

    /// Tiled-model invariants on random layers: cycles ≥ the ideal
    /// compute count and ≥ the transfer demand, dense mode reproduces the
    /// memory-aware closed form, and the sparse flow never loses to dense
    /// (the raw-format escape keeps compressed traffic ≤ dense traffic).
    #[test]
    fn tiled_model_bounds_and_dense_consistency() {
        let mut rng = Pcg32::seeded(41);
        let spec = ConvSpec { stride: 1, pad: 1 };
        for case in 0..8 {
            let icfg = small_cfg(rng.range(1, 4), rng.range(2, 7));
            let mut tcfg = icfg;
            tcfg.mem_model = MemModel::Tiled;
            // Starve the memory system so tiling actually bites.
            tcfg.sram.input_bytes = rng.range(64, 512);
            tcfg.sram.weight_bytes = rng.range(64, 512);
            tcfg.dram_bytes_per_cycle = [0.5, 2.0, 8.0][rng.range(0, 3)];
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 6);
            let h = rng.range(4, 14);
            let w = rng.range(4, 14);
            let input = random_sparse(&mut rng, &[c_in, h, w], 0.5);
            let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], 0.5);
            let mut tr = Trace::disabled();

            let ideal = simulate_layer(
                &input, &weight, None, &icfg, spec, Mode::VectorSparse, false, &mut tr,
            );
            assert_eq!(ideal.stats.transfer_cycles, 0, "case {case}");
            assert_eq!(ideal.stats.compute_cycles, ideal.stats.cycles);

            let tiled = simulate_layer(
                &input, &weight, None, &tcfg, spec, Mode::VectorSparse, false, &mut tr,
            );
            let t = &tiled.stats;
            assert!(t.cycles >= ideal.stats.cycles, "case {case}");
            assert!(t.cycles >= t.transfer_cycles, "case {case}");
            assert!(t.cycles >= t.compute_cycles, "case {case}");
            assert!(t.compute_cycles >= ideal.stats.cycles, "case {case}");
            assert!(t.tiles > 0 && t.fill_cycles <= t.transfer_cycles);
            assert!(t.bw_utilization() <= 1.0);

            let dense = simulate_layer(
                &input, &weight, None, &tcfg, spec, Mode::Dense, false, &mut tr,
            );
            // Dense mode cycles equal the memory-aware closed form used as
            // everyone's denominator.
            assert_eq!(dense.stats.cycles, dense.dense_cycles, "case {case}");
            assert_eq!(
                dense.dense_cycles,
                crate::baselines::dense::dense_mem_cycles(&tcfg, c_in, k_out, h, w, 3),
                "case {case}"
            );
            assert_eq!(tiled.dense_cycles, dense.dense_cycles, "case {case}");
            assert!(t.cycles <= dense.stats.cycles, "case {case}");
        }
    }

    /// Satellite: pin `sync_stall_slots` for a hand-computed 2-filter
    /// group with context-switch cycles in play.
    ///
    /// Setup: `[B=2, R=2, C=3]`, ctx = 2. One channel, `[1,4,3]` input
    /// with nonzero vectors (strip 0: cols {0, 2}; strip 1: col {1}), so
    /// `Σ_s |nzI| = 3` and both strips are live. Filter 0 has nonzero
    /// kernel columns {0, 1}; filter 1 has {2}.
    ///
    ///   work_0 = 2·3 + 2·2 = 10   (pairs + ctx over 2 live strips)
    ///   work_1 = 1·3 + 4   =  7
    ///
    /// The group finishes at the slowest filter (10 cycles): cycles = 10,
    /// and filter 1's array idles 10 − 7 = 3 slots at the group boundary —
    /// the stall formula must charge exactly that (the slowest filter's
    /// total *includes* its context switches, since the other array waits
    /// through them too).
    #[test]
    fn sync_stall_pinned_for_two_filter_group() {
        let mut cfg = SimConfig::paper_4_14_3();
        cfg.pe.arrays = 2;
        cfg.pe.rows = 2;
        cfg.context_switch_cycles = 2;
        let spec = ConvSpec { stride: 1, pad: 1 };

        let mut input = Tensor::zeros(&[1, 4, 3]);
        *input.at3_mut(0, 0, 0) = 1.0; // strip 0, col 0
        *input.at3_mut(0, 1, 2) = 1.0; // strip 0, col 2
        *input.at3_mut(0, 3, 1) = 1.0; // strip 1, col 1
        let mut weight = Tensor::zeros(&[2, 1, 3, 3]);
        *weight.at4_mut(0, 0, 0, 0) = 1.0; // filter 0, kernel col 0
        *weight.at4_mut(0, 0, 1, 1) = 1.0; // filter 0, kernel col 1
        *weight.at4_mut(1, 0, 2, 2) = 1.0; // filter 1, kernel col 2

        let mut tr = Trace::disabled();
        let res = simulate_layer(
            &input, &weight, None, &cfg, spec, Mode::VectorSparse, false, &mut tr,
        );
        assert_eq!(res.stats.cycles, 10);
        assert_eq!(res.stats.overhead_cycles, 4);
        assert_eq!(res.stats.sync_stall_slots, 3);
        // dense reference: 2 (c, strip) blocks × W·KW = 9 pairs + ctx.
        assert_eq!(res.dense_cycles, 22);
        assert_eq!(res.stats.issued_pairs, 9);
        assert_eq!(res.stats.boundary_pairs, 2);

        // Dense mode makes every filter's work equal — zero sync stall,
        // and cycles match the closed-form dense count exactly.
        let dense = simulate_layer(
            &input, &weight, None, &cfg, spec, Mode::Dense, false, &mut tr,
        );
        assert_eq!(dense.stats.cycles, 22);
        assert_eq!(dense.stats.sync_stall_slots, 0);
    }

    /// More arrays per group ⇒ more sync loss (the 92% vs 85% effect).
    #[test]
    fn wider_groups_stall_more() {
        let mut rng = Pcg32::seeded(12);
        let input = random_sparse(&mut rng, &[3, 14, 10], 0.6);
        let weight = random_sparse(&mut rng, &[8, 3, 3, 3], 0.3);
        let spec = ConvSpec::default();
        let mut tr = Trace::disabled();
        let narrow = simulate_layer(
            &input,
            &weight,
            None,
            &small_cfg(2, 7),
            spec,
            Mode::VectorSparse,
            false,
            &mut tr,
        );
        let wide = simulate_layer(
            &input,
            &weight,
            None,
            &small_cfg(8, 7),
            spec,
            Mode::VectorSparse,
            false,
            &mut tr,
        );
        assert!(
            wide.stats.utilization() <= narrow.stats.utilization() + 1e-9,
            "wide {} narrow {}",
            wide.stats.utilization(),
            narrow.stats.utilization()
        );
    }

    /// Issue accounting always closes: issued + skipped = dense pairs.
    #[test]
    fn pair_accounting_closes_randomized() {
        let mut rng = Pcg32::seeded(13);
        for _ in 0..10 {
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 7);
            let h = rng.range(3, 15);
            let w = rng.range(3, 15);
            let cfg = small_cfg(rng.range(1, 5), rng.range(2, 7));
            let input = random_sparse(&mut rng, &[c_in, h, w], 0.4);
            let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], 0.4);
            let mut tr = Trace::disabled();
            let res = simulate_layer(
                &input,
                &weight,
                None,
                &cfg,
                ConvSpec::default(),
                Mode::VectorSparse,
                false,
                &mut tr,
            );
            let strips = h.div_ceil(cfg.pe.rows);
            let n_groups = k_out.div_ceil(cfg.pe.arrays);
            // Dense pair count uses group-padded filters? No: only real
            // filters issue; idle arrays are stalls, not pairs.
            let dense_pairs = (k_out * c_in * strips * w * 3) as u64;
            let _ = n_groups;
            assert_eq!(
                res.stats.issued_pairs + res.stats.skipped_pairs(),
                dense_pairs,
                "accounting mismatch"
            );
        }
    }

    /// ISSUE 5: the analytic (closed-form) scheduler fast paths —
    /// uniform-strip tally collapse, one-tile-per-group demand reuse,
    /// all-uniform tile scaling — must be bit-identical to the exact
    /// per-strip walk across randomized shapes, densities (0, sparse,
    /// dense — dense triggers the uniform path) and both memory models.
    #[test]
    fn analytic_scheduler_matches_exact_walk() {
        let mut rng = Pcg32::seeded(501);
        let spec = ConvSpec { stride: 1, pad: 1 };
        for case in 0..24 {
            let mut cfg = small_cfg(rng.range(1, 4), rng.range(2, 7));
            cfg.context_switch_cycles = rng.range(0, 3) as u64;
            if case % 2 == 0 {
                // Starved memory system: tiling (and its analytic
                // demand paths) actually engage.
                cfg.mem_model = MemModel::Tiled;
                cfg.sram.input_bytes = rng.range(64, 2048);
                cfg.sram.weight_bytes = rng.range(64, 2048);
                cfg.dram_bytes_per_cycle = [0.5, 2.0, 8.0][rng.range(0, 3)];
            }
            let c_in = rng.range(1, 4);
            let k_out = rng.range(1, 7);
            let h = rng.range(4, 18);
            let w = rng.range(4, 12);
            let density = [0.0f32, 0.15, 0.5, 1.0][case % 4];
            let input = if case % 5 == 0 {
                // Vertically tiled rows: every strip identical, so the
                // uniform fast path engages with nontrivial sparsity.
                let strip = random_sparse(&mut rng, &[c_in, cfg.pe.rows, w], 0.4);
                let mut t = Tensor::zeros(&[c_in, h, w]);
                for c in 0..c_in {
                    for row in 0..h {
                        for col in 0..w {
                            *t.at3_mut(c, row, col) = strip.at3(c, row % cfg.pe.rows, col);
                        }
                    }
                }
                t
            } else {
                random_sparse(&mut rng, &[c_in, h, w], density)
            };
            let weight = random_sparse(&mut rng, &[k_out, c_in, 3, 3], 0.5);
            let mut tr = Trace::disabled();
            for mode in [Mode::Dense, Mode::VectorSparse] {
                let fast = simulate_layer(
                    &input, &weight, None, &cfg, spec, mode, false, &mut tr,
                );
                let mut exact_cfg = cfg;
                exact_cfg.exact_scheduler = true;
                let exact = simulate_layer(
                    &input, &weight, None, &exact_cfg, spec, mode, false, &mut tr,
                );
                assert_eq!(fast.stats, exact.stats, "case {case} mode {mode:?}");
                assert_eq!(
                    fast.dense_cycles, exact.dense_cycles,
                    "case {case} mode {mode:?}"
                );
            }
        }
    }

    /// `diag_clip` must reproduce the `Some` set of
    /// `index_unit::output_row` exactly: same valid window, same rows.
    #[test]
    fn diag_clip_matches_output_row() {
        for base in [0usize, 3, 7, 20] {
            for kh in [1usize, 3, 5] {
                for pad in [0usize, 1, 2] {
                    for h_out in [1usize, 5, 9] {
                        for r in [1usize, 4, 7] {
                            let dl = r + kh - 1;
                            let (d_lo, d_hi, row_lo) = diag_clip(base, dl, kh, pad, h_out);
                            assert!(d_lo <= d_hi && d_hi <= dl);
                            for d in 0..dl {
                                let want =
                                    crate::sim::index_unit::output_row(base, d, kh, pad, h_out);
                                if d >= d_lo && d < d_hi {
                                    assert_eq!(
                                        want,
                                        Some(row_lo + (d - d_lo)),
                                        "base {base} kh {kh} pad {pad} h_out {h_out} d {d}"
                                    );
                                } else {
                                    assert_eq!(
                                        want, None,
                                        "base {base} kh {kh} pad {pad} h_out {h_out} d {d}"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "unit stride")]
    fn stride_two_unsupported() {
        let cfg = small_cfg(1, 4);
        let input = Tensor::zeros(&[1, 8, 8]);
        let weight = Tensor::zeros(&[1, 1, 3, 3]);
        let mut tr = Trace::disabled();
        let _ = simulate_layer(
            &input,
            &weight,
            None,
            &cfg,
            ConvSpec { stride: 2, pad: 1 },
            Mode::Dense,
            false,
            &mut tr,
        );
    }
}
