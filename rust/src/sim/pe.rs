//! A single processing element (paper Fig 5): one multiplier and one adder
//! for partial-sum accumulation. The PE holds no weight locally — both
//! operands arrive on the broadcast buses each cycle, which is what lets
//! the same PE serve dense and vector-sparse flows.

/// One PE's combinational function for a cycle: multiply the broadcast
/// input and weight, add the incoming diagonal partial sum.
#[derive(Debug, Default, Clone, Copy)]
pub struct Pe {
    /// MACs this PE has executed (for utilization accounting).
    pub mac_count: u64,
}

impl Pe {
    /// Execute one cycle: `psum_in + input * weight`.
    #[inline]
    pub fn cycle(&mut self, input: f32, weight: f32, psum_in: f32) -> f32 {
        self.mac_count += 1;
        psum_in + input * weight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_semantics() {
        let mut pe = Pe::default();
        assert_eq!(pe.cycle(2.0, 3.0, 1.0), 7.0);
        assert_eq!(pe.cycle(0.0, 5.0, 4.0), 4.0);
        assert_eq!(pe.mac_count, 2);
    }

    #[test]
    fn accumulation_chain() {
        // Three PEs chained diagonally: psum flows through.
        let mut pes = [Pe::default(); 3];
        let inputs = [1.0, 2.0, 3.0];
        let weights = [0.5, 0.25, 0.125];
        let mut psum = 0.0;
        for (pe, (i, w)) in pes.iter_mut().zip(inputs.iter().zip(&weights)) {
            psum = pe.cycle(*i, *w, psum);
        }
        assert!((psum - (0.5 + 0.5 + 0.375)).abs() < 1e-6);
    }
}
