//! External (DRAM) traffic accounting.
//!
//! The paper's Fig 3 flow: inputs and weights are fetched from external
//! memory into SRAM once per reuse round; partial sums stay on chip; only
//! final nonzero output vectors go back out. This model counts the bytes
//! each side moves so the reports can show the traffic advantage of
//! keeping zero vectors out of DRAM entirely.

/// Byte counters for one simulated layer (or an accumulated run).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct DramTraffic {
    /// Input activation bytes read (compressed: nonzero vectors only).
    pub input_read: u64,
    /// Weight bytes read (compressed).
    pub weight_read: u64,
    /// Output bytes written (compressed, post zero-detection).
    pub output_write: u64,
    /// Per-vector index bytes moved alongside the data.
    pub index_bytes: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.input_read + self.weight_read + self.output_write + self.index_bytes
    }

    /// Merge counters (accumulating a whole network run).
    pub fn merge(&mut self, other: &DramTraffic) {
        self.input_read += other.input_read;
        self.weight_read += other.weight_read;
        self.output_write += other.output_write;
        self.index_bytes += other.index_bytes;
    }

    /// Cycles needed to move this traffic at `bytes_per_cycle` (the memory-
    /// bound lower latency bound; the network roofline summary reports it
    /// next to the tiled cycle count).
    pub fn transfer_cycles(&self, bytes_per_cycle: f64) -> u64 {
        cycles_for_bytes(self.total(), bytes_per_cycle)
    }
}

/// Cycles to move `bytes` at `bytes_per_cycle`, rounded up (zero bytes
/// move in zero cycles). The per-tile conversion of the tiled memory
/// model ([`crate::sim::sram::stream_tiles`]).
pub fn cycles_for_bytes(bytes: u64, bytes_per_cycle: f64) -> u64 {
    assert!(bytes_per_cycle > 0.0);
    (bytes as f64 / bytes_per_cycle).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_merge() {
        let a = DramTraffic {
            input_read: 100,
            weight_read: 50,
            output_write: 25,
            index_bytes: 5,
        };
        assert_eq!(a.total(), 180);
        let mut b = DramTraffic::default();
        b.merge(&a);
        b.merge(&a);
        assert_eq!(b.total(), 360);
    }

    #[test]
    fn transfer_cycles_round_up() {
        let t = DramTraffic {
            input_read: 10,
            ..Default::default()
        };
        assert_eq!(t.transfer_cycles(4.0), 3);
        assert_eq!(t.transfer_cycles(10.0), 1);
    }

    #[test]
    fn cycles_for_bytes_rounds_up_and_handles_zero() {
        assert_eq!(cycles_for_bytes(0, 8.0), 0);
        assert_eq!(cycles_for_bytes(1, 8.0), 1);
        assert_eq!(cycles_for_bytes(16, 8.0), 2);
        assert_eq!(cycles_for_bytes(17, 8.0), 3);
    }
}
