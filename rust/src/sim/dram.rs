//! External (DRAM) traffic accounting.
//!
//! The paper's Fig 3 flow: inputs and weights are fetched from external
//! memory into SRAM once per reuse round; partial sums stay on chip; only
//! final nonzero output vectors go back out. This model counts the bytes
//! each side moves so the reports can show the traffic advantage of
//! keeping zero vectors out of DRAM entirely.

/// Byte counters for one simulated layer (or an accumulated run).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct DramTraffic {
    /// Input activation bytes read (compressed: nonzero vectors only).
    pub input_read: u64,
    /// Weight bytes read (compressed).
    pub weight_read: u64,
    /// Output bytes written (compressed, post zero-detection).
    pub output_write: u64,
    /// Per-vector index bytes moved alongside the data.
    pub index_bytes: u64,
}

impl DramTraffic {
    pub fn total(&self) -> u64 {
        self.input_read + self.weight_read + self.output_write + self.index_bytes
    }

    /// Merge counters (accumulating a whole network run).
    pub fn merge(&mut self, other: &DramTraffic) {
        self.input_read += other.input_read;
        self.weight_read += other.weight_read;
        self.output_write += other.output_write;
        self.index_bytes += other.index_bytes;
    }

    /// Cycles needed to move this traffic at `bytes_per_cycle` (the memory-
    /// bound lower latency bound; reported next to compute cycles).
    pub fn transfer_cycles(&self, bytes_per_cycle: f64) -> u64 {
        assert!(bytes_per_cycle > 0.0);
        (self.total() as f64 / bytes_per_cycle).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_merge() {
        let a = DramTraffic {
            input_read: 100,
            weight_read: 50,
            output_write: 25,
            index_bytes: 5,
        };
        assert_eq!(a.total(), 180);
        let mut b = DramTraffic::default();
        b.merge(&a);
        b.merge(&a);
        assert_eq!(b.total(), 360);
    }

    #[test]
    fn transfer_cycles_round_up() {
        let t = DramTraffic {
            input_read: 10,
            ..Default::default()
        };
        assert_eq!(t.transfer_cycles(4.0), 3);
        assert_eq!(t.transfer_cycles(10.0), 1);
    }
}
