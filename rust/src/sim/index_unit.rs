//! The vector index system — the paper's low-overhead answer to the
//! fine-grained designs' indexing/routing cost.
//!
//! Each nonzero vector carries its original position: an input vector its
//! spatial column `i`, a weight vector its kernel column `j`. When the pair
//! `(i, j)` is issued, the partial output column lands at output column
//! `o = i - j + pad`. Pairs whose `o` falls outside `[0, W_out)` still
//! occupy an issue slot (Table I marks them `X`) but their result is
//! discarded — the hardware does not look ahead past them.

/// Output-column index for an issued pair; `None` when the pair is a
/// boundary `X` slot.
#[inline]
pub fn output_col(input_col: usize, weight_col: usize, pad: usize, w_out: usize) -> Option<usize> {
    let o = input_col as isize - weight_col as isize + pad as isize;
    if o >= 0 && (o as usize) < w_out {
        Some(o as usize)
    } else {
        None
    }
}

/// Output-row index for one diagonal element; `None` when outside the
/// output plane. `d` indexes the `R+C-1` diagonal outputs of a cycle.
#[inline]
pub fn output_row(
    strip_base: usize,
    d: usize,
    cols: usize,
    pad: usize,
    h_out: usize,
) -> Option<usize> {
    // PE row r and weight row c satisfy d = r + (C-1) - c, so the output
    // row is strip_base + r - c + pad = strip_base + d - (C-1) + pad.
    let row = strip_base as isize + d as isize - (cols as isize - 1) + pad as isize;
    if row >= 0 && (row as usize) < h_out {
        Some(row as usize)
    } else {
        None
    }
}

/// An issued vector pair, as recorded by the trace (Table I rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IssuedPair {
    /// Spatial column of the input vector.
    pub input_col: usize,
    /// Kernel column of the weight vector.
    pub weight_col: usize,
    /// Destination output column, `None` for boundary `X` slots.
    pub output_col: Option<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I ground truth for the 5x5/pad-1/3x3 example: input col A(=0)
    /// with weight col WA(=0) lands on output col B(=1); with WB(=1) on
    /// A(=0); with WC(=2) out of range (X).
    #[test]
    fn table1_output_columns() {
        let (pad, w_out) = (1, 5);
        assert_eq!(output_col(0, 0, pad, w_out), Some(1)); // A × WA → OB
        assert_eq!(output_col(0, 1, pad, w_out), Some(0)); // A × WB → OA
        assert_eq!(output_col(0, 2, pad, w_out), None); // A × WC → X
        assert_eq!(output_col(4, 0, pad, w_out), None); // E × WA → X (sparse t=7)
        assert_eq!(output_col(4, 1, pad, w_out), Some(4)); // E × WB → OE
    }

    #[test]
    fn output_rows_cover_strip_with_halo() {
        // R=5, C=3, pad=1, strip at base 0, H_out=5: diagonals d=0..6 map
        // to rows -2..4 shifted: d - 2 + 1 = d - 1 → rows -1..5; valid 0..4.
        let (cols, pad, h_out) = (3, 1, 5);
        assert_eq!(output_row(0, 0, cols, pad, h_out), None); // OB0 boundary
        assert_eq!(output_row(0, 1, cols, pad, h_out), Some(0)); // OB1
        assert_eq!(output_row(0, 5, cols, pad, h_out), Some(4)); // OB5
        assert_eq!(output_row(0, 6, cols, pad, h_out), None); // OB6 boundary
    }

    #[test]
    fn strips_tile_without_overlap() {
        // With strips of R rows, rows produced by strip s = s*R + (d-C+1+pad)
        // for d in [0, R+C-1). Verify adjacent strips cover each output row
        // the right number of times for full accumulation: row h receives
        // contributions from diagonals of its own strip and the halo rows of
        // neighbours — here we just verify every output row is reachable.
        let (r, cols, pad, h_out) = (4usize, 3usize, 1usize, 8usize);
        let mut covered = vec![0usize; h_out];
        for s in 0..2 {
            for d in 0..(r + cols - 1) {
                if let Some(row) = output_row(s * r, d, cols, pad, h_out) {
                    covered[row] += 1;
                }
            }
        }
        assert!(covered.iter().all(|&c| c >= 1), "coverage {covered:?}");
    }
}
