//! Kernel/stride mapping — the paper's §II-B extension point.
//!
//! The array is optimized for 3×3 unit-stride kernels ("the most widely
//! [used] filter"); §II-B defers other shapes to "a suitable mapping
//! method [13]". This module implements that mapping so the same PE array
//! serves the rest of the CNN zoo:
//!
//! * **KH < C (e.g. 1×1, 1×K kernels)** — the kernel column is zero-padded
//!   to the array height; padded taps multiply by zero and add nothing, so
//!   the result is exact while keeping the broadcast geometry.
//! * **KH > C (e.g. 5×5, 7×7)** — each kernel column splits into
//!   `ceil(KH/C)` sub-vectors of C taps; each sub-vector issues as its own
//!   weight vector with a shifted accumulation base (the index system adds
//!   `row_offset` to the strip base), exactly like processing a taller
//!   virtual array over multiple passes.
//! * **stride S ≥ 2** — polyphase decomposition: the input splits into S²
//!   phase sub-planes (row/col index mod S) and the kernel into S²
//!   sub-kernels; each phase pair runs as a unit-stride conv on the array
//!   (row-mapped again if its phase kernel height differs from C) and the
//!   partial outputs accumulate in the shared psum buffer. Padded strided
//!   convs materialize the zero border explicitly before phase extraction;
//!   the all-zero border vectors are skipped by the index system in
//!   vector-sparse mode (dense mode pays for them, as real hardware
//!   streaming a padded plane would).
//!
//! ## Compile/execute split
//!
//! The decomposition above is *input-independent*: which sub-kernels exist,
//! their CVF encodes, and their accumulation offsets depend only on the
//! weight tensor, the conv spec and the array geometry. [`compile_conv`]
//! performs it once, producing a [`CompiledConv`] plan with every
//! sub-kernel pre-encoded; [`simulate_compiled`] executes an image against
//! the plan (the only per-image work left on the weight side is zero).
//! The legacy entry points ([`simulate_layer_mapped`],
//! [`simulate_layer_strided`], [`simulate_layer_any`]) are thin wrappers
//! that compile per call — same results, no caching.
//!
//! All mappings reuse [`simulate_layer`] unchanged — the point of the
//! paper's design is that the accumulator flow is index-driven, so remaps
//! only change *which* vectors are issued.

use super::config::{MemModel, SimConfig};
use super::scheduler::{simulate_layer, simulate_layer_encoded, LayerResult, Mode};
use super::stats::SimStats;
use super::trace::Trace;
use crate::sparse::VectorWeights;
use crate::tensor::conv::{out_dim, pad_input, ConvSpec};
use crate::tensor::Tensor;
use std::sync::Arc;

/// One sub-kernel issued on the array: weights padded/split to the array
/// height, plus the accumulation row offset its outputs carry.
#[derive(Debug)]
pub struct MappedKernel {
    pub weight: Tensor,
    /// Added to the strip base when accumulating this sub-kernel's output.
    pub row_offset: usize,
}

/// Split/pad `weight [K,C,KH,KW]` for an array with `cols` PE columns.
pub fn map_kernel_rows(weight: &Tensor, cols: usize) -> Vec<MappedKernel> {
    assert_eq!(weight.ndim(), 4);
    let (k, c, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    let chunks = kh.div_ceil(cols);
    (0..chunks)
        .map(|t| {
            let mut sub = Tensor::zeros(&[k, c, cols, kw]);
            for ki in 0..k {
                for ci in 0..c {
                    for i_local in 0..cols {
                        let i = t * cols + i_local;
                        if i >= kh {
                            break; // zero-pad the tail
                        }
                        for j in 0..kw {
                            *sub.at4_mut(ki, ci, i_local, j) = weight.at4(ki, ci, i, j);
                        }
                    }
                }
            }
            MappedKernel {
                weight: sub,
                row_offset: t * cols,
            }
        })
        .collect()
}

/// A sub-kernel ready to issue: raw tensor (dense/trace paths) plus its
/// CVF encode (timing + sparse functional paths), both behind `Arc` so
/// compiled plans share weight storage with their [`super::super::engine`]
/// layer instead of copying it.
#[derive(Debug, Clone)]
pub struct EncodedKernel {
    pub weight: Arc<Tensor>,
    pub vw: Arc<VectorWeights>,
    /// Added to the strip base when accumulating this sub-kernel's output.
    pub row_offset: usize,
}

/// The input-independent decomposition of one conv layer onto the array.
#[derive(Debug, Clone)]
pub enum ConvPlan {
    /// `KH == C`, unit stride: the native dataflow, no remap.
    Direct { sub: EncodedKernel, spec: ConvSpec },
    /// Unit stride, `KH != C`: row-mapped sub-kernels issued at an enlarged
    /// padding `sub_spec.pad = spec.pad + dp` (see [`compile_conv`]).
    RowMapped {
        subs: Vec<EncodedKernel>,
        spec: ConvSpec,
        sub_spec: ConvSpec,
        dp: usize,
    },
    /// Stride ≥ 2: polyphase phases, each itself a compiled unit-stride
    /// conv on its phase sub-plane.
    Polyphase { spec: ConvSpec, phases: Vec<PhasePlan> },
}

/// One polyphase phase: parity `(pr, pc)` and the compiled unit-stride conv
/// of its phase kernel over the phase sub-plane.
#[derive(Debug, Clone)]
pub struct PhasePlan {
    pub pr: usize,
    pub pc: usize,
    pub conv: CompiledConv,
}

/// A conv layer compiled for a `cols`-column PE array and a fixed input
/// shape: the decomposition plan plus the closed-form dense-cycle inputs.
#[derive(Debug, Clone)]
pub struct CompiledConv {
    pub plan: ConvPlan,
    /// `(plane_h, plane_w, sub_kw)` of every sub-conv the plan issues —
    /// enough to evaluate the dense baseline without simulating.
    pub sub_dims: Vec<[usize; 3]>,
    /// The `[C, H, W]` activation shape the plan was compiled for
    /// (executing a different shape would silently invalidate
    /// [`Self::dense_cycles`], so [`simulate_compiled`] asserts it).
    pub in_shape: [usize; 3],
    pub k_out: usize,
    pub c_in: usize,
    /// Original kernel height/width (pre-mapping).
    pub kh: usize,
    pub kw: usize,
    /// PE columns the plan was compiled for.
    pub cols: usize,
}

impl CompiledConv {
    /// Closed-form dense-flow cycle count of this plan under `cfg` — the
    /// speedup denominator, computable at compile time (it is
    /// input-data-independent). Matches the `dense_cycles` the scheduler
    /// reports when executing the plan, under either memory model: the
    /// tiled model's dense baseline streams each sub-conv's uncompressed
    /// data through the same double-buffered SRAM hierarchy.
    pub fn dense_cycles(&self, cfg: &SimConfig) -> u64 {
        match cfg.mem_model {
            MemModel::Ideal => {
                let groups = self.k_out.div_ceil(cfg.pe.arrays) as u64;
                self.sub_dims
                    .iter()
                    .map(|&[h, w, kw]| {
                        let strips = h.div_ceil(cfg.pe.rows) as u64;
                        let blocks = groups * self.c_in as u64 * strips;
                        blocks * (w as u64) * (kw as u64) + blocks * cfg.context_switch_cycles
                    })
                    .sum()
            }
            MemModel::Tiled => self
                .sub_dims
                .iter()
                .map(|&[h, w, kw]| {
                    crate::baselines::dense::dense_mem_cycles(cfg, self.c_in, self.k_out, h, w, kw)
                })
                .sum(),
        }
    }
}

fn encode_arc(t: &Tensor, pack_vals: bool) -> Arc<VectorWeights> {
    Arc::new(if pack_vals {
        VectorWeights::from_tensor(t)
    } else {
        VectorWeights::index_only(t)
    })
}

/// Compile a conv layer of any supported geometry into its array plan.
///
/// * `in_shape` — the `[C, H, W]` activation shape entering the layer
///   (strided plans need it to size phase planes);
/// * `vw` — optional pre-built CVF encode of `weight` (reused for the
///   native `KH == cols` case; sub-kernels always get fresh encodes);
/// * `pack_vals` — carry value payloads in the encodes (required for the
///   parallel functional dataflow; index-only is enough for timing).
pub fn compile_conv(
    in_shape: [usize; 3],
    weight: Arc<Tensor>,
    vw: Option<Arc<VectorWeights>>,
    cols: usize,
    spec: ConvSpec,
    pack_vals: bool,
) -> CompiledConv {
    assert_eq!(weight.ndim(), 4);
    assert_eq!(in_shape[0], weight.shape()[1], "channel mismatch");
    match spec.stride {
        1 => compile_unit_stride(in_shape, weight, vw, cols, spec, pack_vals),
        s if s >= 2 => compile_polyphase(in_shape, &weight, cols, spec, pack_vals),
        _ => panic!("stride 0 is not a convolution"),
    }
}

fn compile_unit_stride(
    in_shape: [usize; 3],
    weight: Arc<Tensor>,
    vw: Option<Arc<VectorWeights>>,
    cols: usize,
    spec: ConvSpec,
    pack_vals: bool,
) -> CompiledConv {
    assert_eq!(spec.stride, 1);
    let [_, h, w] = in_shape;
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    if kh == cols {
        let sub_vw = vw.unwrap_or_else(|| encode_arc(&weight, pack_vals));
        return CompiledConv {
            plan: ConvPlan::Direct {
                sub: EncodedKernel {
                    weight,
                    vw: sub_vw,
                    row_offset: 0,
                },
                spec,
            },
            sub_dims: vec![[h, w, kw]],
            in_shape,
            k_out,
            c_in,
            kh,
            kw,
            cols,
        };
    }
    compile_row_mapped(in_shape, &weight, cols, spec, pack_vals)
}

/// The `KH != cols`, unit-stride mapping. Borrows the weight tensor — the
/// plan stores only the (small) sub-kernels, never the original, so
/// per-call wrappers avoid copying it.
fn compile_row_mapped(
    in_shape: [usize; 3],
    weight: &Tensor,
    cols: usize,
    spec: ConvSpec,
    pack_vals: bool,
) -> CompiledConv {
    assert_eq!(spec.stride, 1);
    let [_, h, w] = in_shape;
    let (k_out, c_in, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    // The sub-convs run at an enlarged padding p' = p + chunks·C − KH so
    // every needed output row exists for every chunk; output indices then
    // shift by dp = p' − p on both dims (a pure index shift the
    // accumulator's index system applies for free in hardware).
    let mapped = map_kernel_rows(weight, cols);
    let chunks = mapped.len();
    let dp = chunks * cols - kh;
    let sub_spec = ConvSpec {
        stride: 1,
        pad: spec.pad + dp,
    };
    let subs: Vec<EncodedKernel> = mapped
        .into_iter()
        .map(|m| {
            let vw = encode_arc(&m.weight, pack_vals);
            EncodedKernel {
                weight: Arc::new(m.weight),
                vw,
                row_offset: m.row_offset,
            }
        })
        .collect();
    CompiledConv {
        sub_dims: vec![[h, w, kw]; chunks],
        in_shape,
        plan: ConvPlan::RowMapped {
            subs,
            spec,
            sub_spec,
            dp,
        },
        k_out,
        c_in,
        kh,
        kw,
        cols,
    }
}

fn compile_polyphase(
    in_shape: [usize; 3],
    weight: &Tensor,
    cols: usize,
    spec: ConvSpec,
    pack_vals: bool,
) -> CompiledConv {
    let s = spec.stride;
    assert!(s >= 2);
    let [c, h, w] = in_shape;
    let (k_out, kh, kw) = (weight.shape()[0], weight.shape()[2], weight.shape()[3]);
    // Padded strided convs run on the explicitly padded plane (pad 0 after
    // materialization), so phase planes size from the padded dims.
    let (hp_in, wp_in) = (h + 2 * spec.pad, w + 2 * spec.pad);
    let mut phases = Vec::new();
    let mut sub_dims = Vec::new();
    let spec1 = ConvSpec { stride: 1, pad: 0 };
    for pr in 0..s.min(kh) {
        for pc in 0..s.min(kw) {
            let wp = Arc::new(phase_kernel(weight, pr, pc, s));
            let (khp, kwp) = (wp.shape()[2], wp.shape()[3]);
            let (ph, pw) = ((hp_in - pr).div_ceil(s), (wp_in - pc).div_ceil(s));
            if ph < khp || pw < kwp {
                continue; // degenerate phase (tiny plane)
            }
            let inner = compile_unit_stride([c, ph, pw], wp, None, cols, spec1, pack_vals);
            sub_dims.extend(inner.sub_dims.iter().copied());
            phases.push(PhasePlan {
                pr,
                pc,
                conv: inner,
            });
        }
    }
    CompiledConv {
        plan: ConvPlan::Polyphase { spec, phases },
        sub_dims,
        in_shape,
        k_out,
        c_in: c,
        kh,
        kw,
        cols,
    }
}

/// `[K, H_out, W_out]` zeros, pre-filled with per-filter bias when present
/// (the psum buffer's initial state), for functional runs only.
fn bias_filled(
    functional: bool,
    k_out: usize,
    h_out: usize,
    w_out: usize,
    bias: Option<&[f32]>,
) -> Option<Tensor> {
    functional.then(|| {
        let mut t = Tensor::zeros(&[k_out, h_out, w_out]);
        if let Some(b) = bias {
            for (k, &bv) in b.iter().enumerate() {
                for r in 0..h_out {
                    for c in 0..w_out {
                        *t.at3_mut(k, r, c) = bv;
                    }
                }
            }
        }
        t
    })
}

/// Execute one image against a compiled conv plan. Stats accumulate across
/// sub-kernels/phases; the functional output is exact (matches the golden
/// conv of the original geometry).
pub fn simulate_compiled(
    input: &Tensor,
    cc: &CompiledConv,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    assert_eq!(
        cc.cols, cfg.pe.cols,
        "plan compiled for {} PE columns, simulating with {}",
        cc.cols, cfg.pe.cols
    );
    // A different input shape would silently invalidate `sub_dims` /
    // `dense_cycles` — make the misuse loud.
    assert_eq!(
        shape3(input),
        cc.in_shape,
        "plan compiled for input {:?}, executing {:?}",
        cc.in_shape,
        input.shape()
    );
    match &cc.plan {
        ConvPlan::Direct { sub, spec } => simulate_layer_encoded(
            input, &sub.weight, &sub.vw, bias, cfg, *spec, mode, functional, trace,
        ),
        ConvPlan::RowMapped {
            subs,
            spec,
            sub_spec,
            dp,
        } => {
            let dp = *dp;
            let h_out = out_dim(input.shape()[1], cc.kh, *spec);
            let w_out = out_dim(input.shape()[2], cc.kw, *spec);
            let mut stats = SimStats::default();
            let mut dense_cycles = 0u64;
            let mut out = bias_filled(functional, cc.k_out, h_out, w_out, bias);
            for sub in subs {
                // Run the sub-kernel (height = cols) on the unmodified
                // input; its taps sit `row_offset` rows lower in the
                // virtual tall kernel, so its output row `m + row_offset +
                // dp` contributes to full-conv row `m`.
                let res = simulate_layer_encoded(
                    input,
                    &sub.weight,
                    &sub.vw,
                    None,
                    cfg,
                    *sub_spec,
                    mode,
                    functional,
                    trace,
                );
                stats.merge(&res.stats);
                dense_cycles += res.dense_cycles;
                if let (Some(acc), Some(sub_out)) = (out.as_mut(), res.output) {
                    let sub_h = sub_out.shape()[1];
                    let sub_w = sub_out.shape()[2];
                    for k in 0..cc.k_out {
                        for r in 0..h_out {
                            let rs = r + sub.row_offset + dp;
                            if rs >= sub_h {
                                continue;
                            }
                            for c in 0..w_out {
                                let cs = c + dp;
                                if cs >= sub_w {
                                    continue;
                                }
                                *acc.at3_mut(k, r, c) += sub_out.at3(k, rs, cs);
                            }
                        }
                    }
                }
            }
            LayerResult {
                stats,
                dense_cycles,
                output: out,
            }
        }
        ConvPlan::Polyphase { spec, phases } => {
            let s = spec.stride;
            let h_out = out_dim(input.shape()[1], cc.kh, *spec);
            let w_out = out_dim(input.shape()[2], cc.kw, *spec);
            let padded;
            let x: &Tensor = if spec.pad > 0 {
                padded = pad_input(input, spec.pad);
                &padded
            } else {
                input
            };
            let mut stats = SimStats::default();
            let mut dense_cycles = 0u64;
            let mut out = bias_filled(functional, cc.k_out, h_out, w_out, bias);
            for ph in phases {
                let xp = phase_plane(x, ph.pr, ph.pc, s);
                let res = simulate_compiled(&xp, &ph.conv, None, cfg, mode, functional, trace);
                stats.merge(&res.stats);
                dense_cycles += res.dense_cycles;
                if let (Some(acc), Some(sub)) = (out.as_mut(), res.output) {
                    for k in 0..cc.k_out {
                        for r in 0..h_out.min(sub.shape()[1]) {
                            for c in 0..w_out.min(sub.shape()[2]) {
                                *acc.at3_mut(k, r, c) += sub.at3(k, r, c);
                            }
                        }
                    }
                }
            }
            LayerResult {
                stats,
                dense_cycles,
                output: out,
            }
        }
    }
}

fn shape3(t: &Tensor) -> [usize; 3] {
    [t.shape()[0], t.shape()[1], t.shape()[2]]
}

/// Simulate a conv layer of arbitrary kernel height at unit stride by
/// mapping it onto the array (KH != PE columns allowed). Compiles the plan
/// per call — use [`compile_conv`] + [`simulate_compiled`] to amortize.
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_mapped(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    assert_eq!(spec.stride, 1, "use simulate_layer_strided for stride >= 2");
    if weight.shape()[2] == cfg.pe.cols {
        return simulate_layer(input, weight, bias, cfg, spec, mode, functional, trace);
    }
    let pack = functional && !trace.enabled();
    // The row-mapped plan stores only the sub-kernels, so the original
    // weight tensor is borrowed, never copied.
    let cc = compile_row_mapped(shape3(input), weight, cfg.pe.cols, spec, pack);
    simulate_compiled(input, &cc, bias, cfg, mode, functional, trace)
}

/// Simulate a strided (S ≥ 2) conv layer via polyphase decomposition,
/// compiling the plan per call. Padded strided convs are handled by
/// materializing the zero border (see the module doc).
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_strided(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    assert!(spec.stride >= 2, "this mapper is for stride >= 2");
    let pack = functional && !trace.enabled();
    // Polyphase plans store only the phase kernels — borrow, don't copy.
    let cc = compile_polyphase(shape3(input), weight, cfg.pe.cols, spec, pack);
    simulate_compiled(input, &cc, bias, cfg, mode, functional, trace)
}

/// Route a conv of any supported geometry to the right dataflow:
/// native 3-column unit-stride, row-mapped (1×1/5×5/7×7/11×11), or
/// polyphase strided. This is what the per-call (non-compiled) paths use.
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_any(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    match spec.stride {
        0 => panic!("stride 0 is not a convolution"),
        1 => simulate_layer_mapped(input, weight, bias, cfg, spec, mode, functional, trace),
        _ => simulate_layer_strided(input, weight, bias, cfg, spec, mode, functional, trace),
    }
}

/// Polyphase phase extraction: sub-plane of `input` at row/col parity
/// `(pr, pc)` for stride `s`.
pub fn phase_plane(input: &Tensor, pr: usize, pc: usize, s: usize) -> Tensor {
    let (c, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let hp = (h - pr).div_ceil(s);
    let wp = (w - pc).div_ceil(s);
    let mut out = Tensor::zeros(&[c, hp, wp]);
    for ci in 0..c {
        for r in 0..hp {
            for col in 0..wp {
                *out.at3_mut(ci, r, col) = input.at3(ci, s * r + pr, s * col + pc);
            }
        }
    }
    out
}

/// Polyphase sub-kernel at parity `(pr, pc)`: taps `weight[.., i, j]` with
/// `i ≡ pr (mod s)`, `j ≡ pc (mod s)`.
pub fn phase_kernel(weight: &Tensor, pr: usize, pc: usize, s: usize) -> Tensor {
    let (k, c, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    let khp = (kh - pr).div_ceil(s);
    let kwp = (kw - pc).div_ceil(s);
    let mut out = Tensor::zeros(&[k, c, khp.max(1), kwp.max(1)]);
    for ki in 0..k {
        for ci in 0..c {
            for i in 0..khp {
                for j in 0..kwp {
                    if s * i + pr < kh && s * j + pc < kw {
                        *out.at4_mut(ki, ci, i, j) = weight.at4(ki, ci, s * i + pr, s * j + pc);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SimConfig;
    use crate::tensor::conv::conv2d;
    use crate::util::rng::Pcg32;

    fn rand_t(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(
            shape,
            (0..n)
                .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
                .collect(),
        )
    }

    fn cfg(rows: usize) -> SimConfig {
        let mut c = SimConfig::paper_4_14_3();
        c.pe.arrays = 2;
        c.pe.rows = rows;
        c.context_switch_cycles = 0;
        c
    }

    #[test]
    fn one_by_one_kernel_maps_exactly() {
        let mut rng = Pcg32::seeded(61);
        let input = rand_t(&mut rng, &[3, 8, 8], 0.6);
        let weight = rand_t(&mut rng, &[4, 3, 1, 1], 0.7);
        let bias: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
        let spec = ConvSpec { stride: 1, pad: 0 };
        let golden = conv2d(&input, &weight, Some(&bias), spec);
        let mut tr = Trace::disabled();
        let res = simulate_layer_mapped(
            &input,
            &weight,
            Some(&bias),
            &cfg(4),
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        let out = res.output.unwrap();
        assert!(
            golden.allclose(&out, 1e-3, 1e-3),
            "diff {}",
            golden.max_abs_diff(&out)
        );
    }

    #[test]
    fn five_by_five_kernel_maps_exactly() {
        let mut rng = Pcg32::seeded(62);
        let input = rand_t(&mut rng, &[2, 10, 10], 0.5);
        let weight = rand_t(&mut rng, &[3, 2, 5, 5], 0.5);
        let spec = ConvSpec { stride: 1, pad: 2 };
        let golden = conv2d(&input, &weight, None, spec);
        let mut tr = Trace::disabled();
        let res = simulate_layer_mapped(
            &input,
            &weight,
            None,
            &cfg(5),
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        let out = res.output.unwrap();
        assert!(
            golden.allclose(&out, 1e-3, 1e-3),
            "diff {}",
            golden.max_abs_diff(&out)
        );
        // 5-tall kernels need 2 passes of the 3-col array.
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn native_3x3_passes_through_unmapped() {
        let mut rng = Pcg32::seeded(63);
        let input = rand_t(&mut rng, &[2, 8, 8], 0.5);
        let weight = rand_t(&mut rng, &[2, 2, 3, 3], 0.5);
        let spec = ConvSpec::default();
        let mut tr = Trace::disabled();
        let a = simulate_layer_mapped(
            &input, &weight, None, &cfg(4), spec, Mode::VectorSparse, false, &mut tr,
        );
        let b = simulate_layer(
            &input, &weight, None, &cfg(4), spec, Mode::VectorSparse, false, &mut tr,
        );
        assert_eq!(a.stats.cycles, b.stats.cycles);
    }

    #[test]
    fn map_kernel_rows_pads_and_splits() {
        let mut rng = Pcg32::seeded(64);
        let weight = rand_t(&mut rng, &[1, 1, 5, 3], 1.0);
        let mapped = map_kernel_rows(&weight, 3);
        assert_eq!(mapped.len(), 2);
        assert_eq!(mapped[0].row_offset, 0);
        assert_eq!(mapped[1].row_offset, 3);
        // Chunk 1 holds taps 3,4 and a zero row.
        assert_eq!(mapped[1].weight.at4(0, 0, 0, 0), weight.at4(0, 0, 3, 0));
        assert_eq!(mapped[1].weight.at4(0, 0, 2, 0), 0.0);
        // Tap mass is preserved across chunks.
        let total: f32 = weight.data().iter().sum();
        let mapped_total: f32 = mapped.iter().flat_map(|m| m.weight.data()).sum();
        assert!((total - mapped_total).abs() < 1e-6);
    }

    /// Polyphase stride-2: sum of phase convs equals the strided conv.
    #[test]
    fn polyphase_stride2_equals_direct() {
        let mut rng = Pcg32::seeded(65);
        for _ in 0..6 {
            let c = rng.range(1, 4);
            let k = rng.range(1, 4);
            let h = rng.range(6, 12) & !1; // even for clean phases
            let w = rng.range(6, 12) & !1;
            let input = rand_t(&mut rng, &[c, h, w], 0.7);
            let weight = rand_t(&mut rng, &[k, c, 3, 3], 0.7);
            let spec2 = ConvSpec { stride: 2, pad: 0 };
            let golden = conv2d(&input, &weight, None, spec2);

            // Σ over 4 phases of unit-stride convs on the sub-planes.
            let mut acc = Tensor::zeros(golden.shape());
            for pr in 0..2 {
                for pc in 0..2 {
                    let xp = phase_plane(&input, pr, pc, 2);
                    let wp = phase_kernel(&weight, pr, pc, 2);
                    let spec1 = ConvSpec { stride: 1, pad: 0 };
                    if xp.shape()[1] < wp.shape()[2] || xp.shape()[2] < wp.shape()[3] {
                        continue;
                    }
                    let sub = conv2d(&xp, &wp, None, spec1);
                    for ki in 0..k {
                        for r in 0..golden.shape()[1] {
                            for col in 0..golden.shape()[2] {
                                if r < sub.shape()[1] && col < sub.shape()[2] {
                                    *acc.at3_mut(ki, r, col) += sub.at3(ki, r, col);
                                }
                            }
                        }
                    }
                }
            }
            assert!(
                golden.allclose(&acc, 1e-3, 1e-3),
                "polyphase mismatch {}",
                golden.max_abs_diff(&acc)
            );
        }
    }

    /// Strided convs with padding and stride > 2 (the AlexNet stem and
    /// ResNet downsamples) run exactly through the polyphase mapper.
    #[test]
    fn strided_padded_kernels_map_exactly() {
        let mut rng = Pcg32::seeded(66);
        let cases: &[(usize, usize, usize, usize)] = &[
            // (k, stride, pad, hw)
            (11, 4, 2, 19),
            (7, 2, 3, 12),
            (3, 2, 1, 10),
            (1, 2, 0, 8),
            (5, 3, 2, 13),
        ];
        for &(k, stride, pad, hw) in cases {
            let input = rand_t(&mut rng, &[2, hw, hw], 0.6);
            let weight = rand_t(&mut rng, &[3, 2, k, k], 0.6);
            let spec = ConvSpec { stride, pad };
            let golden = conv2d(&input, &weight, None, spec);
            let mut tr = Trace::disabled();
            let res = simulate_layer_strided(
                &input,
                &weight,
                None,
                &cfg(4),
                spec,
                Mode::VectorSparse,
                true,
                &mut tr,
            );
            let out = res.output.unwrap();
            assert_eq!(out.shape(), golden.shape(), "k={k} s={stride} p={pad}");
            assert!(
                golden.allclose(&out, 1e-3, 1e-3),
                "k={k} s={stride} p={pad}: diff {}",
                golden.max_abs_diff(&out)
            );
            assert!(res.stats.cycles > 0 && res.stats.cycles <= res.dense_cycles);
        }
    }

    /// A compiled plan must reproduce the per-call wrappers bit-for-bit —
    /// same cycles, same stats, same functional output — and its
    /// closed-form dense baseline must match the scheduler's.
    #[test]
    fn compiled_plan_matches_per_call_simulation() {
        let mut rng = Pcg32::seeded(67);
        let cfgv = cfg(4);
        let cases: &[(usize, usize, usize, usize)] =
            &[(3, 1, 1, 9), (5, 1, 2, 9), (1, 1, 0, 8), (3, 2, 1, 10), (11, 4, 2, 15)];
        for &(k, stride, pad, hw) in cases {
            let weight = Arc::new(rand_t(&mut rng, &[3, 2, k, k], 0.5));
            let spec = ConvSpec { stride, pad };
            let cc = compile_conv([2, hw, hw], weight.clone(), None, cfgv.pe.cols, spec, true);
            for _ in 0..2 {
                let input = rand_t(&mut rng, &[2, hw, hw], 0.5);
                let mut tr = Trace::disabled();
                let a = simulate_compiled(
                    &input,
                    &cc,
                    None,
                    &cfgv,
                    Mode::VectorSparse,
                    true,
                    &mut tr,
                );
                let b = simulate_layer_any(
                    &input,
                    &weight,
                    None,
                    &cfgv,
                    spec,
                    Mode::VectorSparse,
                    true,
                    &mut tr,
                );
                assert_eq!(a.stats.cycles, b.stats.cycles, "k={k} s={stride}");
                assert_eq!(a.stats.issued_pairs, b.stats.issued_pairs);
                assert_eq!(a.dense_cycles, b.dense_cycles);
                assert_eq!(
                    a.output.unwrap().data(),
                    b.output.unwrap().data(),
                    "k={k} s={stride}: functional outputs must be bit-identical"
                );
                // Closed-form dense baseline == simulated dense baseline.
                assert_eq!(cc.dense_cycles(&cfgv), b.dense_cycles, "k={k} s={stride}");
            }
        }
    }
}
