//! Kernel/stride mapping — the paper's §II-B extension point.
//!
//! The array is optimized for 3×3 unit-stride kernels ("the most widely
//! [used] filter"); §II-B defers other shapes to "a suitable mapping
//! method [13]". This module implements that mapping so the same PE array
//! serves the rest of the CNN zoo:
//!
//! * **KH < C (e.g. 1×1, 1×K kernels)** — the kernel column is zero-padded
//!   to the array height; padded taps multiply by zero and add nothing, so
//!   the result is exact while keeping the broadcast geometry.
//! * **KH > C (e.g. 5×5, 7×7)** — each kernel column splits into
//!   `ceil(KH/C)` sub-vectors of C taps; each sub-vector issues as its own
//!   weight vector with a shifted accumulation base (the index system adds
//!   `row_offset` to the strip base), exactly like processing a taller
//!   virtual array over multiple passes.
//! * **stride 2** — polyphase decomposition: the input splits into 4
//!   phase sub-planes (even/odd rows × even/odd cols) and the kernel into
//!   4 sub-kernels; each phase pair runs as a unit-stride conv on the
//!   array and the partial outputs accumulate in the shared psum buffer.
//!
//! All mappings reuse [`simulate_layer`] unchanged — the point of the
//! paper's design is that the accumulator flow is index-driven, so remaps
//! only change *which* vectors are issued.

use super::config::SimConfig;
use super::scheduler::{simulate_layer, LayerResult, Mode};
use super::stats::SimStats;
use super::trace::Trace;
use crate::tensor::conv::ConvSpec;
use crate::tensor::Tensor;

/// One sub-kernel issued on the array: weights padded/split to the array
/// height, plus the accumulation row offset its outputs carry.
#[derive(Debug)]
pub struct MappedKernel {
    pub weight: Tensor,
    /// Added to the strip base when accumulating this sub-kernel's output.
    pub row_offset: usize,
}

/// Split/pad `weight [K,C,KH,KW]` for an array with `cols` PE columns.
pub fn map_kernel_rows(weight: &Tensor, cols: usize) -> Vec<MappedKernel> {
    assert_eq!(weight.ndim(), 4);
    let (k, c, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    let chunks = kh.div_ceil(cols);
    (0..chunks)
        .map(|t| {
            let mut sub = Tensor::zeros(&[k, c, cols, kw]);
            for ki in 0..k {
                for ci in 0..c {
                    for i_local in 0..cols {
                        let i = t * cols + i_local;
                        if i >= kh {
                            break; // zero-pad the tail
                        }
                        for j in 0..kw {
                            *sub.at4_mut(ki, ci, i_local, j) = weight.at4(ki, ci, i, j);
                        }
                    }
                }
            }
            MappedKernel {
                weight: sub,
                row_offset: t * cols,
            }
        })
        .collect()
}

/// Simulate a conv layer of arbitrary kernel height at unit stride by
/// mapping it onto the array (KH != PE columns allowed). Stats accumulate
/// across sub-kernels; the functional output is exact.
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_mapped(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    assert_eq!(spec.stride, 1, "use simulate_layer_stride2 for stride 2");
    let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
    let h = input.shape()[1];
    let w = input.shape()[2];
    let h_out = crate::tensor::conv::out_dim(h, kh, spec);
    let w_out = crate::tensor::conv::out_dim(w, kw, spec);
    let k_out = weight.shape()[0];

    if kh == cfg.pe.cols {
        return simulate_layer(input, weight, bias, cfg, spec, mode, functional, trace);
    }

    let mapped = map_kernel_rows(weight, cfg.pe.cols);
    let mut stats = SimStats::default();
    let mut dense_cycles = 0u64;
    let mut out = functional.then(|| {
        let mut t = Tensor::zeros(&[k_out, h_out, w_out]);
        if let Some(b) = bias {
            for (k, &bv) in b.iter().enumerate() {
                for r in 0..h_out {
                    for c in 0..w_out {
                        *t.at3_mut(k, r, c) = bv;
                    }
                }
            }
        }
        t
    });

    let _ = h;
    // The sub-convs run at an enlarged padding p' = p + chunks·C − KH so
    // every needed output row exists for every chunk; output indices then
    // shift by dp = p' − p on both dims (a pure index shift the
    // accumulator's index system applies for free in hardware).
    let chunks = mapped.len();
    let dp = chunks * cfg.pe.cols - kh;
    let sub_spec = ConvSpec {
        stride: 1,
        pad: spec.pad + dp,
    };
    for sub in &mapped {
        // Run the sub-kernel (height = cols) on the unmodified input; its
        // taps sit `row_offset` rows lower in the virtual tall kernel, so
        // its output row `m + row_offset + dp` contributes to full-conv
        // row `m` (O[m] += O_sub[m + t·C + dp]).
        let res = simulate_layer(
            input,
            &sub.weight,
            None,
            cfg,
            sub_spec,
            mode,
            functional,
            trace,
        );
        stats.merge(&res.stats);
        dense_cycles += res.dense_cycles;
        if let (Some(acc), Some(sub_out)) = (out.as_mut(), res.output) {
            let sub_h = sub_out.shape()[1];
            let sub_w = sub_out.shape()[2];
            for k in 0..k_out {
                for r in 0..h_out {
                    let rs = r + sub.row_offset + dp;
                    if rs >= sub_h {
                        continue;
                    }
                    for c in 0..w_out {
                        let cs = c + dp;
                        if cs >= sub_w {
                            continue;
                        }
                        *acc.at3_mut(k, r, c) += sub_out.at3(k, rs, cs);
                    }
                }
            }
        }
    }
    LayerResult {
        stats,
        dense_cycles,
        output: out,
    }
}

/// Simulate a stride-2 conv layer via polyphase decomposition: 4 phase
/// sub-planes × matching sub-kernels run as unit-stride convs on the
/// array (each routed through [`simulate_layer_mapped`], since sub-kernel
/// heights are 1 or 2); partial outputs accumulate in the shared psum
/// buffer. Cycle stats sum across phases.
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_stride2(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    assert_eq!(spec.stride, 2, "this mapper is for stride 2");
    assert_eq!(
        spec.pad, 0,
        "stride-2 polyphase mapping currently supports pad 0 \
         (pad the input tensor explicitly for padded strided convs)"
    );
    let (k_out, kh, kw) = (weight.shape()[0], weight.shape()[2], weight.shape()[3]);
    let h_out = crate::tensor::conv::out_dim(input.shape()[1], kh, spec);
    let w_out = crate::tensor::conv::out_dim(input.shape()[2], kw, spec);

    let mut stats = SimStats::default();
    let mut dense_cycles = 0u64;
    let mut out = functional.then(|| {
        let mut t = Tensor::zeros(&[k_out, h_out, w_out]);
        if let Some(b) = bias {
            for (k, &bv) in b.iter().enumerate() {
                for r in 0..h_out {
                    for c in 0..w_out {
                        *t.at3_mut(k, r, c) = bv;
                    }
                }
            }
        }
        t
    });

    let spec1 = ConvSpec { stride: 1, pad: 0 };
    for pr in 0..2usize.min(kh) {
        for pc in 0..2usize.min(kw) {
            let xp = phase_plane(input, pr, pc);
            let wp = phase_kernel(weight, pr, pc);
            if xp.shape()[1] < wp.shape()[2] || xp.shape()[2] < wp.shape()[3] {
                continue; // degenerate phase (tiny plane)
            }
            let res = simulate_layer_mapped(
                &xp, &wp, None, cfg, spec1, mode, functional, trace,
            );
            stats.merge(&res.stats);
            dense_cycles += res.dense_cycles;
            if let (Some(acc), Some(sub)) = (out.as_mut(), res.output) {
                for k in 0..k_out {
                    for r in 0..h_out.min(sub.shape()[1]) {
                        for c in 0..w_out.min(sub.shape()[2]) {
                            *acc.at3_mut(k, r, c) += sub.at3(k, r, c);
                        }
                    }
                }
            }
        }
    }
    LayerResult {
        stats,
        dense_cycles,
        output: out,
    }
}

/// Route a conv of any supported geometry to the right dataflow:
/// native 3-column unit-stride, row-mapped (1×1/5×5/7×7), or polyphase
/// stride-2. This is what the coordinator calls.
#[allow(clippy::too_many_arguments)]
pub fn simulate_layer_any(
    input: &Tensor,
    weight: &Tensor,
    bias: Option<&[f32]>,
    cfg: &SimConfig,
    spec: ConvSpec,
    mode: Mode,
    functional: bool,
    trace: &mut Trace,
) -> LayerResult {
    match spec.stride {
        1 => simulate_layer_mapped(input, weight, bias, cfg, spec, mode, functional, trace),
        2 => simulate_layer_stride2(input, weight, bias, cfg, spec, mode, functional, trace),
        s => panic!("stride {s} unsupported (paper §II-B mappings cover 1 and 2)"),
    }
}

/// Polyphase phase extraction: sub-plane of `input` at row/col parity
/// `(pr, pc)` for stride 2.
pub fn phase_plane(input: &Tensor, pr: usize, pc: usize) -> Tensor {
    let (c, h, w) = (input.shape()[0], input.shape()[1], input.shape()[2]);
    let hp = (h - pr).div_ceil(2);
    let wp = (w - pc).div_ceil(2);
    let mut out = Tensor::zeros(&[c, hp, wp]);
    for ci in 0..c {
        for r in 0..hp {
            for col in 0..wp {
                *out.at3_mut(ci, r, col) = input.at3(ci, 2 * r + pr, 2 * col + pc);
            }
        }
    }
    out
}

/// Polyphase sub-kernel at parity `(pr, pc)`: taps `weight[.., i, j]` with
/// `i ≡ pr (mod 2)`, `j ≡ pc (mod 2)`.
pub fn phase_kernel(weight: &Tensor, pr: usize, pc: usize) -> Tensor {
    let (k, c, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    let khp = (kh - pr).div_ceil(2);
    let kwp = (kw - pc).div_ceil(2);
    let mut out = Tensor::zeros(&[k, c, khp.max(1), kwp.max(1)]);
    for ki in 0..k {
        for ci in 0..c {
            for i in 0..khp {
                for j in 0..kwp {
                    if 2 * i + pr < kh && 2 * j + pc < kw {
                        *out.at4_mut(ki, ci, i, j) = weight.at4(ki, ci, 2 * i + pr, 2 * j + pc);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::SimConfig;
    use crate::tensor::conv::conv2d;
    use crate::util::rng::Pcg32;

    fn rand_t(rng: &mut Pcg32, shape: &[usize], density: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_vec(
            shape,
            (0..n)
                .map(|_| if rng.bernoulli(density) { rng.normal() } else { 0.0 })
                .collect(),
        )
    }

    fn cfg(rows: usize) -> SimConfig {
        let mut c = SimConfig::paper_4_14_3();
        c.pe.arrays = 2;
        c.pe.rows = rows;
        c.context_switch_cycles = 0;
        c
    }

    #[test]
    fn one_by_one_kernel_maps_exactly() {
        let mut rng = Pcg32::seeded(61);
        let input = rand_t(&mut rng, &[3, 8, 8], 0.6);
        let weight = rand_t(&mut rng, &[4, 3, 1, 1], 0.7);
        let bias: Vec<f32> = (0..4).map(|_| rng.normal()).collect();
        let spec = ConvSpec { stride: 1, pad: 0 };
        let golden = conv2d(&input, &weight, Some(&bias), spec);
        let mut tr = Trace::disabled();
        let res = simulate_layer_mapped(
            &input,
            &weight,
            Some(&bias),
            &cfg(4),
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        let out = res.output.unwrap();
        assert!(
            golden.allclose(&out, 1e-3, 1e-3),
            "diff {}",
            golden.max_abs_diff(&out)
        );
    }

    #[test]
    fn five_by_five_kernel_maps_exactly() {
        let mut rng = Pcg32::seeded(62);
        let input = rand_t(&mut rng, &[2, 10, 10], 0.5);
        let weight = rand_t(&mut rng, &[3, 2, 5, 5], 0.5);
        let spec = ConvSpec { stride: 1, pad: 2 };
        let golden = conv2d(&input, &weight, None, spec);
        let mut tr = Trace::disabled();
        let res = simulate_layer_mapped(
            &input,
            &weight,
            None,
            &cfg(5),
            spec,
            Mode::VectorSparse,
            true,
            &mut tr,
        );
        let out = res.output.unwrap();
        assert!(
            golden.allclose(&out, 1e-3, 1e-3),
            "diff {}",
            golden.max_abs_diff(&out)
        );
        // 5-tall kernels need 2 passes of the 3-col array.
        assert!(res.stats.cycles > 0);
    }

    #[test]
    fn native_3x3_passes_through_unmapped() {
        let mut rng = Pcg32::seeded(63);
        let input = rand_t(&mut rng, &[2, 8, 8], 0.5);
        let weight = rand_t(&mut rng, &[2, 2, 3, 3], 0.5);
        let spec = ConvSpec::default();
        let mut tr = Trace::disabled();
        let a = simulate_layer_mapped(
            &input, &weight, None, &cfg(4), spec, Mode::VectorSparse, false, &mut tr,
        );
        let b = simulate_layer(
            &input, &weight, None, &cfg(4), spec, Mode::VectorSparse, false, &mut tr,
        );
        assert_eq!(a.stats.cycles, b.stats.cycles);
    }

    #[test]
    fn map_kernel_rows_pads_and_splits() {
        let mut rng = Pcg32::seeded(64);
        let weight = rand_t(&mut rng, &[1, 1, 5, 3], 1.0);
        let mapped = map_kernel_rows(&weight, 3);
        assert_eq!(mapped.len(), 2);
        assert_eq!(mapped[0].row_offset, 0);
        assert_eq!(mapped[1].row_offset, 3);
        // Chunk 1 holds taps 3,4 and a zero row.
        assert_eq!(mapped[1].weight.at4(0, 0, 0, 0), weight.at4(0, 0, 3, 0));
        assert_eq!(mapped[1].weight.at4(0, 0, 2, 0), 0.0);
        // Tap mass is preserved across chunks.
        let total: f32 = weight.data().iter().sum();
        let mapped_total: f32 = mapped.iter().flat_map(|m| m.weight.data()).sum();
        assert!((total - mapped_total).abs() < 1e-6);
    }

    /// Polyphase stride-2: sum of phase convs equals the strided conv.
    #[test]
    fn polyphase_stride2_equals_direct() {
        let mut rng = Pcg32::seeded(65);
        for _ in 0..6 {
            let c = rng.range(1, 4);
            let k = rng.range(1, 4);
            let h = rng.range(6, 12) & !1; // even for clean phases
            let w = rng.range(6, 12) & !1;
            let input = rand_t(&mut rng, &[c, h, w], 0.7);
            let weight = rand_t(&mut rng, &[k, c, 3, 3], 0.7);
            let spec2 = ConvSpec { stride: 2, pad: 0 };
            let golden = conv2d(&input, &weight, None, spec2);

            // Σ over 4 phases of unit-stride convs on the sub-planes.
            let mut acc = Tensor::zeros(golden.shape());
            for pr in 0..2 {
                for pc in 0..2 {
                    let xp = phase_plane(&input, pr, pc);
                    let wp = phase_kernel(&weight, pr, pc);
                    let spec1 = ConvSpec { stride: 1, pad: 0 };
                    if xp.shape()[1] < wp.shape()[2] || xp.shape()[2] < wp.shape()[3] {
                        continue;
                    }
                    let sub = conv2d(&xp, &wp, None, spec1);
                    for ki in 0..k {
                        for r in 0..golden.shape()[1] {
                            for col in 0..golden.shape()[2] {
                                if r < sub.shape()[1] && col < sub.shape()[2] {
                                    *acc.at3_mut(ki, r, col) += sub.at3(ki, r, col);
                                }
                            }
                        }
                    }
                }
            }
            assert!(
                golden.allclose(&acc, 1e-3, 1e-3),
                "polyphase mismatch {}",
                golden.max_abs_diff(&acc)
            );
        }
    }
}
