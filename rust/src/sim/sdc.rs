//! Silent-data-corruption (SDC) model (ISSUE 10): seeded bit-flip
//! injection, the detection-stack coverage model, and the knobs that
//! price protection into the cycle model.
//!
//! CVF compression amplifies upsets: one flipped index word redirects an
//! entire vector's partial sums, one flipped payload exponent poisons an
//! output plane. This module supplies the *deterministic* ingredients
//! the engine ([`crate::engine`]) and the serving fleet
//! ([`crate::serve::fleet`]) thread through:
//!
//! * [`SdcSpec`] — the injected upset mix, parsed from the CLI `--sdc`
//!   grammar (`flip:RATE,weight:F,act:F,acc:F,protect,scrub:MS,
//!   quarantine:N,ovh:F,budget:N`).
//! * [`generate_sdc_plan`] — a seeded, pre-materialized timeline of
//!   per-instance flips on dedicated [`Pcg32`] streams
//!   ([`SDC_STREAM_BASE`], disjoint from the arrival stream and the PR 6
//!   fault streams), each event carrying its taxonomy site and a
//!   pre-drawn detection roll — the event loop itself draws nothing, so
//!   zero-SDC runs stay byte-identical and flip replays are
//!   bit-reproducible.
//! * [`coverage`] — what fraction of consequential flips per
//!   [`SdcSite`] the protection stack (structural CVF validation +
//!   ABFT column checksums + periodic weight scrubbing) catches.
//! * [`IntegrityCounters`] — the injected / masked / detected /
//!   corrected / silent ledger both layers report.
//! * [`EngineSdc`] — the engine-path injection knobs: real bit flips
//!   into tensors and CVF words per layer, detected by
//!   [`crate::tensor::ops::abft_check`] + [`CvfError`]-typed validation
//!   and recovered by bounded per-layer re-execution.
//!
//! [`CvfError`]: crate::sparse::vector_format::CvfError

use crate::util::rng::Pcg32;
use anyhow::{bail, Context, Result};

/// Base PCG32 stream id for per-instance SDC flip plans: instance `i`
/// draws from stream `BASE + i`. Disjoint from the arrival stream (1),
/// the dispatch stream (3), the traffic streams (2), the PR 6 fault
/// streams (`0x0F00 + 2i`, `REQ_FAULT_STREAM = 7`), and the engine SDC
/// streams below, so turning flips on never perturbs any other draw.
pub const SDC_STREAM_BASE: u64 = 0x5DC0;

/// Base PCG32 stream id for the engine path's per-layer injection
/// draws: layer `l` uses `ENGINE_BASE + l`. Offset far past any
/// realistic fleet size so serve-side and engine-side plans never share
/// a stream even under one seed.
pub const SDC_ENGINE_STREAM_BASE: u64 = SDC_STREAM_BASE + 0x4000;

/// Where an upset lands, the ISSUE 10 fault taxonomy. The site decides
/// which detector can see it and therefore its [`coverage`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SdcSite {
    /// SRAM-resident weight CVF words, flipped once and then read by
    /// every batch until a scrub or a cold reload notices.
    Weight,
    /// Activation CVF index/payload words in flight for one layer.
    Activation,
    /// A MAC-group partial sum — corrupts the output of the batch
    /// currently executing.
    Accumulator,
}

impl SdcSite {
    /// Short label for reports and trace markers.
    pub fn label(&self) -> &'static str {
        match self {
            SdcSite::Weight => "weight",
            SdcSite::Activation => "act",
            SdcSite::Accumulator => "acc",
        }
    }
}

/// Detection coverage of the protection stack per site: the fraction of
/// *consequential* flips (those that land in live state) it catches.
///
/// * Weight — structural CVF validation over the resident encode plus
///   the scrub's checksum recompute; only payload flips that stay
///   in-grid and sub-tolerance escape.
/// * Activation — index-word flips are fully caught structurally
///   (bounds / monotonicity / occupancy cross-check, see
///   `vector_format::validate`), but payload flips enter the matmul on
///   both sides of the ABFT identity and escape it — the weakest site.
/// * Accumulator — lands after the checksum row was formed, exactly
///   what ABFT column sums see; only sub-tolerance mantissa flips hide.
pub fn coverage(site: SdcSite) -> f64 {
    match site {
        SdcSite::Weight => 0.98,
        SdcSite::Activation => 0.94,
        SdcSite::Accumulator => 0.97,
    }
}

/// Injected SDC mix and protection knobs for one serving run. Rates are
/// per instance; fractions weight the taxonomy draw.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SdcSpec {
    /// Upset arrivals per instance-second (Poisson). 0 = never.
    pub flip_per_sec: f64,
    /// Taxonomy mixture weight for [`SdcSite::Weight`].
    pub weight_frac: f64,
    /// Taxonomy mixture weight for [`SdcSite::Activation`].
    pub act_frac: f64,
    /// Taxonomy mixture weight for [`SdcSite::Accumulator`].
    pub acc_frac: f64,
    /// Protection stack on: structural validation + ABFT checksums +
    /// weight scrubbing + bounded re-execution, all charged in cycles.
    pub protect: bool,
    /// Weight-scrub period in milliseconds (protected runs re-verify
    /// resident weights on this cadence).
    pub scrub_ms: f64,
    /// Quarantine threshold: a chip whose detected-corruption count
    /// reaches this is taken out of rotation for good. 0 = never.
    pub quarantine: u32,
    /// Fractional service-time overhead charged while protected (the
    /// checksum rows, validation walks, and scrub interference).
    pub overhead_frac: f64,
    /// Per-batch re-execution budget on detection before the batch's
    /// requests are failed into the `RobustnessPolicy` retry path.
    pub reexec_budget: u32,
}

impl SdcSpec {
    /// No injected upsets: fully inert, the zero-SDC configuration is
    /// byte-identical to the pre-SDC simulator.
    pub fn none() -> SdcSpec {
        SdcSpec {
            flip_per_sec: 0.0,
            weight_frac: 0.3,
            act_frac: 0.5,
            acc_frac: 0.2,
            protect: false,
            scrub_ms: 2.0,
            quarantine: 0,
            overhead_frac: 0.02,
            reexec_budget: 2,
        }
    }

    /// True when flips never fire — the plan is empty, no scrub events
    /// are scheduled, no overhead is charged, nothing is reported.
    pub fn is_none(&self) -> bool {
        self.flip_per_sec == 0.0
    }

    /// Parse the CLI `--sdc` grammar: comma-separated `key:value` pairs
    /// plus the bare `protect` word. Keys: `flip` (upsets per
    /// instance-second), `weight`/`act`/`acc` (taxonomy mixture
    /// weights, >= 0, not all zero), `scrub` (ms), `quarantine`
    /// (detected-flip threshold, 0 = off), `ovh` (fractional overhead
    /// in [0, 1)), `budget` (re-executions per batch). Unspecified keys
    /// keep the [`SdcSpec::none`] defaults.
    pub fn parse(s: &str) -> Result<SdcSpec> {
        let mut spec = SdcSpec::none();
        if s.trim().is_empty() {
            bail!("--sdc spec is empty (example: flip:100,protect,scrub:2)");
        }
        for part in s.split(',') {
            if part == "protect" {
                spec.protect = true;
                continue;
            }
            let Some((key, val)) = part.split_once(':') else {
                bail!("--sdc: '{part}' is not key:value or 'protect' (example: flip:100)");
            };
            let num: f64 = val
                .parse()
                .with_context(|| format!("--sdc {key}: cannot parse '{val}'"))?;
            if !num.is_finite() {
                bail!("--sdc {key}: '{val}' is not finite");
            }
            match key {
                "flip" => {
                    anyhow::ensure!(num >= 0.0, "--sdc flip: rate must be >= 0, got {num}");
                    spec.flip_per_sec = num;
                }
                "weight" => {
                    anyhow::ensure!(num >= 0.0, "--sdc weight: fraction must be >= 0");
                    spec.weight_frac = num;
                }
                "act" => {
                    anyhow::ensure!(num >= 0.0, "--sdc act: fraction must be >= 0");
                    spec.act_frac = num;
                }
                "acc" => {
                    anyhow::ensure!(num >= 0.0, "--sdc acc: fraction must be >= 0");
                    spec.acc_frac = num;
                }
                "scrub" => {
                    anyhow::ensure!(num > 0.0, "--sdc scrub: must be > 0 ms, got {num}");
                    spec.scrub_ms = num;
                }
                "quarantine" => {
                    anyhow::ensure!(
                        num >= 0.0 && num.fract() == 0.0,
                        "--sdc quarantine: must be a whole count >= 0, got {num}"
                    );
                    spec.quarantine = num as u32;
                }
                "ovh" => {
                    anyhow::ensure!(
                        (0.0..1.0).contains(&num),
                        "--sdc ovh: overhead fraction must be in [0, 1), got {num}"
                    );
                    spec.overhead_frac = num;
                }
                "budget" => {
                    anyhow::ensure!(
                        num >= 0.0 && num.fract() == 0.0,
                        "--sdc budget: must be a whole count >= 0, got {num}"
                    );
                    spec.reexec_budget = num as u32;
                }
                other => bail!(
                    "--sdc: unknown key '{other}' \
                     (known: flip, weight, act, acc, protect, scrub, quarantine, ovh, budget)"
                ),
            }
        }
        anyhow::ensure!(
            spec.weight_frac + spec.act_frac + spec.acc_frac > 0.0,
            "--sdc: taxonomy fractions must not all be zero"
        );
        Ok(spec)
    }

    /// Short label for reports.
    pub fn label(&self) -> String {
        if self.is_none() {
            return "none".to_string();
        }
        let mut s = format!(
            "flip {}/s (w:{} a:{} c:{})",
            self.flip_per_sec, self.weight_frac, self.act_frac, self.acc_frac
        );
        if self.protect {
            s.push_str(&format!(
                " | protected scrub {}ms ovh {} budget {}",
                self.scrub_ms, self.overhead_frac, self.reexec_budget
            ));
            if self.quarantine > 0 {
                s.push_str(&format!(" quarantine {}", self.quarantine));
            }
        } else {
            s.push_str(" | unprotected");
        }
        s
    }

    /// Expected composite detection coverage over the taxonomy mix —
    /// what a protected run should converge to.
    pub fn expected_coverage(&self) -> f64 {
        let total = self.weight_frac + self.act_frac + self.acc_frac;
        if total <= 0.0 {
            return 0.0;
        }
        (self.weight_frac * coverage(SdcSite::Weight)
            + self.act_frac * coverage(SdcSite::Activation)
            + self.acc_frac * coverage(SdcSite::Accumulator))
            / total
    }
}

/// One planned upset: `site` on `instance` at `cycle`; `roll` is the
/// pre-drawn uniform compared against [`coverage`] at handling time so
/// the event loop never consults an RNG.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SdcEvent {
    pub cycle: u64,
    pub instance: usize,
    pub site: SdcSite,
    pub roll: f32,
}

/// Exponential gap draw, semantics identical to
/// `serve::traffic::exp_interarrival` (kept local so the accelerator
/// model never depends on the serving layer).
fn exp_gap(rng: &mut Pcg32, mean_cycles: f64) -> u64 {
    let u = 1.0 - rng.f32() as f64;
    ((-u.ln() * mean_cycles).ceil() as u64).max(1)
}

/// Materialize the seeded flip timeline for a fleet of `instances` over
/// `horizon` cycles at `clock_hz` cycles/sec: per-instance Poisson
/// arrivals on stream `SDC_STREAM_BASE + i`, each event carrying its
/// taxonomy site and detection roll. Returned sorted by `(cycle,
/// instance)`, ready to enqueue ahead of the arrival process.
/// Deterministic per `(spec, seed)`; empty when `spec.is_none()`.
pub fn generate_sdc_plan(
    spec: &SdcSpec,
    instances: usize,
    horizon: u64,
    clock_hz: f64,
    seed: u64,
) -> Vec<SdcEvent> {
    let mut plan: Vec<SdcEvent> = Vec::new();
    if spec.is_none() {
        return plan;
    }
    let total = spec.weight_frac + spec.act_frac + spec.acc_frac;
    let (w_cut, a_cut) = (
        (spec.weight_frac / total) as f32,
        ((spec.weight_frac + spec.act_frac) / total) as f32,
    );
    let mean_gap = clock_hz / spec.flip_per_sec;
    for i in 0..instances {
        let mut rng = Pcg32::new(seed, SDC_STREAM_BASE + i as u64);
        let mut t = 0u64;
        loop {
            t += exp_gap(&mut rng, mean_gap);
            if t > horizon {
                break;
            }
            let u = rng.f32();
            let site = if u < w_cut {
                SdcSite::Weight
            } else if u < a_cut {
                SdcSite::Activation
            } else {
                SdcSite::Accumulator
            };
            plan.push(SdcEvent {
                cycle: t,
                instance: i,
                site,
                roll: rng.f32(),
            });
        }
    }
    plan.sort_by_key(|e| (e.cycle, e.instance));
    plan
}

/// Protection's price in the cycle model: the checksum rows, validation
/// walks, and scrub interference inflate a base service time by
/// `overhead_frac` (ceil so protection is never free).
pub fn protected_cycles(base: u64, overhead_frac: f64) -> u64 {
    base + (base as f64 * overhead_frac).ceil() as u64
}

/// Precision-aware ABFT noise floor for
/// [`crate::tensor::ops::abft_check`]: fake-quantized payloads still
/// accumulate in f32, so the floor is f32's unit roundoff with modest
/// headroom at the coarser grids (their dequantized magnitudes cluster
/// on fewer, larger steps).
pub fn abft_unit_round(precision: crate::sim::config::Precision) -> f64 {
    use crate::sim::config::Precision;
    let scale = match precision {
        Precision::F32 => 1.0,
        Precision::Int16 => 2.0,
        Precision::Int8 => 4.0,
    };
    scale * f32::EPSILON as f64
}

/// The injected / masked / detected / corrected / silent ledger both
/// the engine and the fleet report. `masked` counts flips that landed
/// in dead state (an idle chip's transient activation/accumulator
/// words) — the architecturally-masked population standard SDC
/// accounting excludes from detection rates.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IntegrityCounters {
    pub injected: u64,
    pub masked: u64,
    pub detected: u64,
    pub corrected: u64,
    pub silent: u64,
}

impl IntegrityCounters {
    /// Detected fraction of consequential (non-masked) flips.
    pub fn detection_rate(&self) -> f64 {
        let consequential = self.injected.saturating_sub(self.masked);
        if consequential == 0 {
            return 1.0;
        }
        self.detected as f64 / consequential as f64
    }

    /// Conservation check: every consequential flip is detected or
    /// silent.
    pub fn consistent(&self) -> bool {
        self.injected >= self.masked
            && self.detected + self.silent == self.injected - self.masked
            && self.corrected <= self.detected
    }
}

/// Engine-path injection knobs ([`crate::engine::execute::RunOptions`]):
/// real bit flips into the layer tensors and CVF words, detected by
/// ABFT + structural validation, recovered by bounded per-layer
/// re-execution. `None` on the options struct keeps the engine
/// byte-identical to the pre-SDC path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineSdc {
    /// Bit flips injected per conv layer (exact count, not a rate —
    /// keeps small-network tests deterministic and meaningful).
    pub flips_per_layer: u32,
    /// Seed for the per-layer injection streams
    /// (`SDC_ENGINE_STREAM_BASE + layer`).
    pub seed: u64,
    /// Run the detection stack and bounded re-execution; off = inject
    /// only (the unprotected arm).
    pub protect: bool,
    /// Re-execution budget per layer on detection.
    pub reexec_budget: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let s = SdcSpec::parse(
            "flip:120,weight:0.2,act:0.6,acc:0.2,protect,scrub:3,quarantine:5,ovh:0.03,budget:1",
        )
        .unwrap();
        assert_eq!(s.flip_per_sec, 120.0);
        assert_eq!(s.weight_frac, 0.2);
        assert_eq!(s.act_frac, 0.6);
        assert_eq!(s.acc_frac, 0.2);
        assert!(s.protect);
        assert_eq!(s.scrub_ms, 3.0);
        assert_eq!(s.quarantine, 5);
        assert_eq!(s.overhead_frac, 0.03);
        assert_eq!(s.reexec_budget, 1);
        assert!(!s.is_none());
        assert!(s.label().contains("protected"));
    }

    #[test]
    fn parse_partial_keeps_defaults_and_errors_are_specific() {
        let s = SdcSpec::parse("flip:50").unwrap();
        assert_eq!(s.flip_per_sec, 50.0);
        assert!(!s.protect);
        assert_eq!(s.scrub_ms, SdcSpec::none().scrub_ms);
        assert!(s.label().contains("unprotected"));
        for (input, needle) in [
            ("", "empty"),
            ("flip", "key:value"),
            ("flip:abc", "cannot parse"),
            ("flip:-1", ">= 0"),
            ("ovh:1.5", "[0, 1)"),
            ("scrub:0", "> 0"),
            ("quarantine:1.5", "whole count"),
            ("bogus:1", "unknown key"),
            ("flip:1,weight:0,act:0,acc:0", "not all be zero"),
        ] {
            let err = SdcSpec::parse(input).unwrap_err();
            assert!(
                format!("{err:#}").contains(needle),
                "input '{input}': expected '{needle}' in '{err:#}'"
            );
        }
    }

    #[test]
    fn none_spec_is_inert() {
        assert!(SdcSpec::none().is_none());
        assert_eq!(SdcSpec::none().label(), "none");
        let plan = generate_sdc_plan(&SdcSpec::none(), 8, 1_000_000_000, 5e8, 42);
        assert!(plan.is_empty());
    }

    #[test]
    fn plan_is_deterministic_sorted_and_site_mixed() {
        let spec = SdcSpec::parse("flip:400,protect").unwrap();
        let a = generate_sdc_plan(&spec, 4, 500_000_000, 5e8, 9);
        let b = generate_sdc_plan(&spec, 4, 500_000_000, 5e8, 9);
        assert_eq!(a, b, "same (spec, seed) must replay bit-identically");
        assert!(a.len() > 100, "rate high enough to fire: {}", a.len());
        assert!(a.windows(2).all(|w| (w[0].cycle, w[0].instance) <= (w[1].cycle, w[1].instance)));
        let c = generate_sdc_plan(&spec, 4, 500_000_000, 5e8, 10);
        assert_ne!(a, c, "different seeds produce different timelines");
        // All three sites appear under the default mixture, and the
        // rolls are genuine uniforms.
        for site in [SdcSite::Weight, SdcSite::Activation, SdcSite::Accumulator] {
            assert!(a.iter().any(|e| e.site == site), "{site:?} never drawn");
        }
        assert!(a.iter().all(|e| (0.0..1.0).contains(&e.roll)));
    }

    #[test]
    fn expected_coverage_clears_the_acceptance_bar() {
        let spec = SdcSpec::parse("flip:100,protect").unwrap();
        assert!(
            spec.expected_coverage() >= 0.9,
            "default taxonomy coverage {} < 0.9",
            spec.expected_coverage()
        );
        for site in [SdcSite::Weight, SdcSite::Activation, SdcSite::Accumulator] {
            assert!((0.9..1.0).contains(&coverage(site)), "{site:?}");
        }
    }

    #[test]
    fn counters_conserve_and_rate_is_sane() {
        let c = IntegrityCounters {
            injected: 100,
            masked: 20,
            detected: 75,
            corrected: 70,
            silent: 5,
        };
        assert!(c.consistent());
        assert!((c.detection_rate() - 0.9375).abs() < 1e-12);
        assert_eq!(IntegrityCounters::default().detection_rate(), 1.0);
        assert!(IntegrityCounters::default().consistent());
    }

    #[test]
    fn protection_overhead_is_charged_and_bounded() {
        assert_eq!(protected_cycles(1000, 0.02), 1020);
        assert_eq!(protected_cycles(0, 0.02), 0);
        assert_eq!(protected_cycles(1, 0.02), 2, "ceil: protection is never free");
        use crate::sim::config::Precision;
        assert!(abft_unit_round(Precision::F32) < abft_unit_round(Precision::Int8));
    }
}
