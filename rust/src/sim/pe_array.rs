//! One PE array (paper Fig 4): `R` rows × `C` columns.
//!
//! Per cycle the array receives an `R`-element input column vector
//! (broadcast horizontally — row `r` of every PE column sees `input[r]`)
//! and a `C`-element weight column vector (broadcast vertically — column
//! `c` of every row sees `weight[c]`). PE `(r, c)` computes
//! `input[r] * weight[c]`, and products on the same diagonal `r - c` are
//! summed *in the same cycle* into one partial output element, yielding an
//! `R + C - 1`-element partial output column per cycle.

use super::pe::Pe;

/// One R×C PE array with its diagonal adder tree.
#[derive(Debug, Clone)]
pub struct PeArray {
    pub rows: usize,
    pub cols: usize,
    pes: Vec<Pe>,
    /// Cycles this array has been issued work.
    pub busy_cycles: u64,
}

impl PeArray {
    pub fn new(rows: usize, cols: usize) -> PeArray {
        PeArray {
            rows,
            cols,
            pes: vec![Pe::default(); rows * cols],
            busy_cycles: 0,
        }
    }

    /// Length of the partial output column produced each cycle.
    pub fn out_len(&self) -> usize {
        self.rows + self.cols - 1
    }

    /// Execute one cycle: full `R x C` multiply + diagonal reduction.
    ///
    /// `out[d]` sums products with `r - c + (C-1) = d`; element `d` maps to
    /// output row `strip_base + d - (C-1) + pad` (the caller applies the
    /// offset — see [`super::accumulator`]).
    pub fn cycle(&mut self, input: &[f32], weight: &[f32]) -> Vec<f32> {
        assert_eq!(input.len(), self.rows, "input vector length != rows");
        assert_eq!(weight.len(), self.cols, "weight vector length != cols");
        let mut out = vec![0.0f32; self.out_len()];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let p = self.pes[r * self.cols + c].cycle(input[r], weight[c], 0.0);
                out[r + (self.cols - 1) - c] += p;
            }
        }
        self.busy_cycles += 1;
        out
    }

    /// Total MACs executed by all PEs.
    pub fn total_macs(&self) -> u64 {
        self.pes.iter().map(|p| p.mac_count).sum()
    }
}

/// Pure helper: the diagonal reduction of one cycle without PE state
/// (used by the timing-only scheduler's functional cross-checks and by the
/// accumulator tests).
pub fn diagonal_product(input: &[f32], weight: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; input.len() + weight.len() - 1];
    diagonal_product_into(input, weight, &mut out);
    out
}

/// Allocation-free [`diagonal_product`]: writes the `R + C - 1` diagonal
/// sums into a caller-owned scratch buffer. The functional scheduler calls
/// this once per issued pair, so the hot loop makes no heap allocations
/// (EXPERIMENTS.md §Perf).
#[inline]
pub fn diagonal_product_into(input: &[f32], weight: &[f32], out: &mut [f32]) {
    let cols = weight.len();
    debug_assert_eq!(out.len(), input.len() + cols - 1);
    out.fill(0.0);
    for (r, &iv) in input.iter().enumerate() {
        for (c, &wv) in weight.iter().enumerate() {
            out[r + (cols - 1) - c] += iv * wv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig 8 t=1 block: input A1..A5, weights WA1..WA3.
    /// Row Am of the output diagonal must equal Σ_i A_{m+i-1}·WA_i — i.e.
    /// the 1-D convolution (correlation) of the column with the kernel
    /// column, including the OB0/OB6 boundary entries.
    #[test]
    fn fig8_t1_diagonal_sums() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0]; // A1..A5
        let w = [10.0, 20.0, 30.0]; // WA1..WA3
        let mut arr = PeArray::new(5, 3);
        let out = arr.cycle(&a, &w);
        assert_eq!(out.len(), 7); // OB0..OB6
        // out[d] = Σ_{r-c+2=d} a[r]*w[c]
        // OB0 (d=0): r=0,c=2 → A1*WA3 = 30
        assert_eq!(out[0], 30.0);
        // OB1 (d=1): A1*WA2 + A2*WA3 = 20 + 60 = 80
        assert_eq!(out[1], 80.0);
        // OB2 (d=2): A1*WA1 + A2*WA2 + A3*WA3 = 10+40+90 = 140
        assert_eq!(out[2], 140.0);
        // OB6 (d=6): A5*WA1 = 50
        assert_eq!(out[6], 50.0);
        assert_eq!(arr.total_macs(), 15);
        assert_eq!(arr.busy_cycles, 1);
    }

    #[test]
    fn diagonal_product_matches_array() {
        let a = [0.5, -1.0, 2.0];
        let w = [1.0, 0.0, -2.0];
        let mut arr = PeArray::new(3, 3);
        assert_eq!(arr.cycle(&a, &w), diagonal_product(&a, &w));
    }

    #[test]
    fn diagonal_product_into_reuses_dirty_scratch() {
        let a = [1.0, 2.0];
        let w = [3.0, 4.0];
        let mut scratch = vec![9.0f32; 3];
        diagonal_product_into(&a, &w, &mut scratch);
        assert_eq!(scratch, diagonal_product(&a, &w));
    }

    #[test]
    fn diagonal_is_1d_correlation_with_flip() {
        // out[d] = Σ_c in[d - (C-1) + c] * w[c] — verify against a direct
        // correlation for random vectors.
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(21);
        for _ in 0..20 {
            let r = rng.range(1, 10);
            let c = rng.range(1, 5);
            let input: Vec<f32> = (0..r).map(|_| rng.normal()).collect();
            let weight: Vec<f32> = (0..c).map(|_| rng.normal()).collect();
            let out = diagonal_product(&input, &weight);
            for (d, &o) in out.iter().enumerate() {
                let mut want = 0.0f32;
                for (ci, &wv) in weight.iter().enumerate() {
                    let ri = d as isize - (c as isize - 1) + ci as isize;
                    if ri >= 0 && (ri as usize) < r {
                        want += input[ri as usize] * wv;
                    }
                }
                assert!((o - want).abs() < 1e-5, "d={d}: {o} vs {want}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "input vector length")]
    fn wrong_input_length_panics() {
        let mut arr = PeArray::new(4, 3);
        let _ = arr.cycle(&[1.0; 3], &[1.0; 3]);
    }
}
