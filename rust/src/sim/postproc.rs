//! Post-processing unit (Fig 3): activation function, optional
//! normalization, and **zero detection** — the block that turns the conv
//! output back into compressed nonzero vectors before it leaves for DRAM,
//! creating the input sparsity the *next* layer's scheduler exploits.

use crate::sparse::VectorActivations;
use crate::tensor::conv::relu_inplace;
use crate::tensor::Tensor;

/// Result of post-processing one layer output.
#[derive(Debug)]
pub struct PostprocResult {
    /// Activated output (ReLU applied), still dense in memory.
    pub output: Tensor,
    /// Elements zeroed by ReLU (zero-detection statistic).
    pub zeroed_elems: usize,
    /// Vector-compressed view at vector length `r` — what is actually sent
    /// to DRAM (`None` when `r == 0`, i.e. final layer).
    pub compressed: Option<VectorActivations>,
}

/// Apply ReLU + zero detection + vector compression at vector length `r`.
pub fn postprocess(mut output: Tensor, r: usize) -> PostprocResult {
    let zeroed_elems = relu_inplace(&mut output);
    let compressed = if r > 0 {
        // Index-only: downstream consumers only count vectors/bytes.
        Some(VectorActivations::index_only(&output, r))
    } else {
        None
    };
    PostprocResult {
        output,
        zeroed_elems,
        compressed,
    }
}

/// Bytes written to DRAM for a compressed activation tensor: the nonzero
/// vectors' payload plus one index entry per vector.
pub fn output_dram_bytes(va: &VectorActivations, bytes_per_elem: usize, index_bytes: usize) -> u64 {
    (va.sram_elems() * bytes_per_elem + va.index_entries() * index_bytes) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_then_compress() {
        let t = Tensor::from_vec(
            &[1, 4, 2],
            vec![1.0, -1.0, 2.0, -2.0, -3.0, -4.0, -5.0, -6.0],
        );
        let res = postprocess(t, 2);
        assert_eq!(res.zeroed_elems, 6);
        // After ReLU: strip 0 has col 0 nonzero (1.0, 2.0), col 1 zero;
        // strip 1 all zero.
        let va = res.compressed.unwrap();
        assert_eq!(va.nonzero_vectors(), 1);
        assert!(va.occupied(0, 0, 0));
        assert!(!va.occupied(0, 0, 1));
        assert!(!va.occupied(0, 1, 0));
    }

    #[test]
    fn no_compression_when_r_zero() {
        let t = Tensor::from_vec(&[1, 2, 2], vec![1.0, -1.0, 0.5, 2.0]);
        let res = postprocess(t, 0);
        assert!(res.compressed.is_none());
        assert_eq!(res.zeroed_elems, 1);
    }

    #[test]
    fn dram_bytes_count_payload_and_index() {
        let t = Tensor::from_vec(&[1, 4, 2], vec![1.0; 8]);
        let va = VectorActivations::from_tensor(&t, 2);
        // 4 nonzero vectors × 2 elems × 2 bytes + 4 × 2 index bytes = 24.
        assert_eq!(output_dram_bytes(&va, 2, 2), 24);
    }
}
