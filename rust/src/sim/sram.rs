//! SRAM buffer models (Fig 3's input / weight / partial-sum / output
//! buffers with their controllers), and the tiled double-buffered
//! execution model built on them.
//!
//! The buffers are accounting models: they track resident bytes, peak
//! occupancy and overflow-driven refetches — enough to reproduce the
//! paper's architectural numbers without RTL-level port modelling.
//!
//! [`TilePlan`] splits a conv layer into SRAM-sized tiles (input-row
//! strips × filter groups) at compile time; [`stream_tiles`] then drives a
//! tile sequence through the double-buffered hierarchy, charging each tile
//! `max(compute, transfer)` with a serial prologue fill — the
//! [`crate::sim::config::MemModel::Tiled`] cycle accounting.

use super::config::{PeConfig, SramConfig};

/// One SRAM buffer with a capacity and occupancy/traffic counters.
#[derive(Debug, Clone)]
pub struct SramBuffer {
    pub name: &'static str,
    pub capacity_bytes: usize,
    resident_bytes: usize,
    /// Peak resident bytes observed.
    pub peak_bytes: usize,
    /// Total bytes written into the buffer (fill traffic).
    pub bytes_filled: u64,
    /// Fills rejected for capacity (each forces a DRAM refetch round).
    pub overflows: u64,
}

impl SramBuffer {
    pub fn new(name: &'static str, capacity_bytes: usize) -> SramBuffer {
        SramBuffer {
            name,
            capacity_bytes,
            resident_bytes: 0,
            peak_bytes: 0,
            bytes_filled: 0,
            overflows: 0,
        }
    }

    /// Try to make `bytes` resident. Returns `true` if they fit alongside
    /// the current contents; on `false` the caller must evict and refetch
    /// (counted in `overflows`).
    pub fn fill(&mut self, bytes: usize) -> bool {
        if self.resident_bytes + bytes > self.capacity_bytes {
            self.overflows += 1;
            return false;
        }
        self.resident_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.resident_bytes);
        self.bytes_filled += bytes as u64;
        true
    }

    /// Evict everything (context switch to a new tile/layer).
    pub fn clear(&mut self) {
        self.resident_bytes = 0;
    }

    /// Currently resident bytes.
    pub fn resident(&self) -> usize {
        self.resident_bytes
    }

    /// Whether `bytes` would fit in an empty buffer at all.
    pub fn fits_empty(&self, bytes: usize) -> bool {
        bytes <= self.capacity_bytes
    }
}

/// How one conv layer (or mapped sub-conv) splits into SRAM-sized tiles.
///
/// Input-independent: derived from the layer shape, the PE geometry and
/// the [`SramConfig`] capacities — the input side is provisioned for the
/// worst case (a fully dense strip), so the plan can be computed at
/// compile time and reused for every image.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Input strips (`R` input rows each) in the layer.
    pub strips: usize,
    /// Strips streamed per tile: as many full-height dense strips as fit
    /// in half the input buffer (the other half prefetches the next tile).
    pub strips_per_tile: usize,
    /// Input tiles per filter group: `ceil(strips / strips_per_tile)`.
    pub tiles_per_group: usize,
    /// Filter groups: `ceil(K / B)`.
    pub groups: usize,
    /// Worst-case (dense) bytes of one full-height input strip.
    pub dense_strip_bytes: usize,
    /// The largest filter group's weights fit in half the weight buffer
    /// (double buffered); when false the group re-streams its weights on
    /// every input tile.
    pub weight_group_fits: bool,
    /// The psum buffer holds one strip of partial output columns per
    /// array (`B * (R + C - 1) * W_out` elements).
    pub psum_fits: bool,
}

impl TilePlan {
    /// Plan the tiling of a sub-conv over input `[c_in, h, w]` with output
    /// plane width `w_out` and `k_out` filters. `max_group_weight_bytes`
    /// is the largest filter-group footprint the weight buffer must hold
    /// (compressed for the sparse flow, dense for the dense baseline).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        sram: &SramConfig,
        pe: &PeConfig,
        c_in: usize,
        h: usize,
        w: usize,
        w_out: usize,
        k_out: usize,
        max_group_weight_bytes: usize,
    ) -> TilePlan {
        let r = pe.rows;
        let strips = h.div_ceil(r).max(1);
        let dense_strip_bytes = c_in * r * w * sram.bytes_per_elem;
        let half_in = (sram.input_bytes / 2).max(1);
        let strips_per_tile = (half_in / dense_strip_bytes.max(1)).clamp(1, strips);
        let tiles_per_group = strips.div_ceil(strips_per_tile);
        let groups = k_out.div_ceil(pe.arrays.max(1)).max(1);
        let weight_group_fits = max_group_weight_bytes <= sram.weight_bytes / 2;
        let psum_bytes = pe.arrays * (r + pe.cols - 1) * w_out * sram.bytes_per_elem;
        let psum_fits = psum_bytes <= sram.psum_bytes;
        TilePlan {
            strips,
            strips_per_tile,
            tiles_per_group,
            groups,
            dense_strip_bytes,
            weight_group_fits,
            psum_fits,
        }
    }

    /// Total tiles the layer executes: one per (group, input tile).
    pub fn total_tiles(&self) -> usize {
        self.groups * self.tiles_per_group
    }

    /// Strip index range of input tile `t` (within any group).
    pub fn tile_strips(&self, t: usize) -> std::ops::Range<usize> {
        let lo = t * self.strips_per_tile;
        lo..((t + 1) * self.strips_per_tile).min(self.strips)
    }
}

/// One tile's demand on the array and the memory system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileDemand {
    /// Compute cycles the slowest array in the group needs for this tile.
    pub compute: u64,
    /// Input bytes fetched from DRAM for this tile (0 when resident).
    pub input_bytes: u64,
    /// Weight bytes fetched from DRAM for this tile (0 when the group's
    /// weights are already resident).
    pub weight_bytes: u64,
}

/// Result of streaming a tile sequence through the double-buffered SRAM.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TiledTiming {
    /// Total cycles: `>= max(compute_cycles, transfer_cycles)` always.
    pub cycles: u64,
    /// Sum of per-tile compute cycles (tile-synchronized occupancy).
    pub compute_cycles: u64,
    /// Sum of per-tile DRAM transfer cycles.
    pub transfer_cycles: u64,
    /// Transfer cycles that could not hide behind compute: the prologue
    /// fill of the first tile plus every non-double-bufferable
    /// (overflowing) tile.
    pub fill_cycles: u64,
    /// Tiles streamed.
    pub tiles: u64,
    /// Tiles whose working set overflowed a buffer half (fetched without
    /// overlap).
    pub overflows: u64,
    /// Peak bytes resident in the input buffer half.
    pub input_peak: u64,
    /// Peak bytes resident in the weight buffer half.
    pub weight_peak: u64,
}

/// Drive `demands` through the double-buffered input/weight SRAM model at
/// `bytes_per_cycle` of DRAM bandwidth.
///
/// Tile `i`'s compute overlaps tile `i+1`'s transfer when the prefetch
/// fits the spare buffer halves ([`SramBuffer::fill`] is the live check);
/// the first fill is a serial prologue, an overflowing tile loses the
/// overlap, and the last tile's compute drains with nothing left to
/// prefetch. The result satisfies
/// `cycles >= max(compute_cycles, transfer_cycles)`.
pub fn stream_tiles(
    sram: &SramConfig,
    bytes_per_cycle: f64,
    demands: &[TileDemand],
) -> TiledTiming {
    let mut out = TiledTiming {
        tiles: demands.len() as u64,
        ..TiledTiming::default()
    };
    if demands.is_empty() {
        return out;
    }
    let mut in_buf = SramBuffer::new("input", (sram.input_bytes / 2).max(1));
    let mut w_buf = SramBuffer::new("weight", (sram.weight_bytes / 2).max(1));
    // Per tile: transfer cycles and whether the fetch double-buffers.
    let mut transfers: Vec<(u64, bool)> = Vec::with_capacity(demands.len());
    for d in demands {
        in_buf.clear();
        w_buf.clear();
        let in_ok = d.input_bytes == 0 || in_buf.fill(d.input_bytes as usize);
        let w_ok = d.weight_bytes == 0 || w_buf.fill(d.weight_bytes as usize);
        if !(in_ok && w_ok) {
            out.overflows += 1;
        }
        let t = super::dram::cycles_for_bytes(d.input_bytes + d.weight_bytes, bytes_per_cycle);
        transfers.push((t, in_ok && w_ok));
        out.transfer_cycles += t;
        out.compute_cycles += d.compute;
    }
    // Prologue: the first tile's fill has nothing to hide behind.
    out.cycles += transfers[0].0;
    out.fill_cycles += transfers[0].0;
    for (i, d) in demands.iter().enumerate() {
        match transfers.get(i + 1) {
            Some(&(t_next, true)) => out.cycles += d.compute.max(t_next),
            Some(&(t_next, false)) => {
                out.cycles += d.compute + t_next;
                out.fill_cycles += t_next;
            }
            // Pipeline drain: the last tile computes with the bus idle.
            None => out.cycles += d.compute,
        }
    }
    out.input_peak = in_buf.peak_bytes as u64;
    out.weight_peak = w_buf.peak_bytes as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_and_peak_tracking() {
        let mut b = SramBuffer::new("input", 100);
        assert!(b.fill(60));
        assert!(b.fill(30));
        assert_eq!(b.resident(), 90);
        assert_eq!(b.peak_bytes, 90);
        assert!(!b.fill(20)); // would exceed
        assert_eq!(b.overflows, 1);
        b.clear();
        assert_eq!(b.resident(), 0);
        assert_eq!(b.peak_bytes, 90); // peak persists
        assert!(b.fill(20));
        assert_eq!(b.bytes_filled, 110);
    }

    #[test]
    fn fits_empty_is_capacity_check() {
        let b = SramBuffer::new("w", 64);
        assert!(b.fits_empty(64));
        assert!(!b.fits_empty(65));
    }

    fn plan_cfg(input_bytes: usize, weight_bytes: usize) -> SramConfig {
        SramConfig {
            input_bytes,
            weight_bytes,
            psum_bytes: 1024,
            output_bytes: 1024,
            bytes_per_elem: 2,
        }
    }

    #[test]
    fn tile_plan_splits_strips_by_half_buffer() {
        let pe = PeConfig {
            arrays: 2,
            rows: 4,
            cols: 3,
        };
        // 2 channels, 16 rows, 8 cols: 4 strips of 2*4*8*2 = 128 bytes.
        // Half of a 512-byte input buffer holds 2 dense strips.
        let plan = TilePlan::new(&plan_cfg(512, 512), &pe, 2, 16, 8, 8, 5, 100);
        assert_eq!(plan.strips, 4);
        assert_eq!(plan.dense_strip_bytes, 128);
        assert_eq!(plan.strips_per_tile, 2);
        assert_eq!(plan.tiles_per_group, 2);
        assert_eq!(plan.groups, 3); // ceil(5 / 2)
        assert_eq!(plan.total_tiles(), 6);
        assert_eq!(plan.tile_strips(0), 0..2);
        assert_eq!(plan.tile_strips(1), 2..4);
        assert!(plan.weight_group_fits); // 100 <= 256
        let tight = TilePlan::new(&plan_cfg(512, 512), &pe, 2, 16, 8, 8, 5, 300);
        assert!(!tight.weight_group_fits);
        // A strip larger than the half-buffer still streams one at a time.
        let tiny = TilePlan::new(&plan_cfg(64, 512), &pe, 2, 16, 8, 8, 5, 100);
        assert_eq!(tiny.strips_per_tile, 1);
        assert_eq!(tiny.tiles_per_group, 4);
    }

    #[test]
    fn stream_tiles_overlaps_transfer_with_compute() {
        // Two tiles, everything fits: cycles = T0 + max(C0, T1) + C1.
        let sram = plan_cfg(200, 200);
        let demands = [
            TileDemand {
                compute: 10,
                input_bytes: 16,
                weight_bytes: 0,
            },
            TileDemand {
                compute: 3,
                input_bytes: 24,
                weight_bytes: 0,
            },
        ];
        let t = stream_tiles(&sram, 4.0, &demands);
        // T0 = 4, T1 = 6: 4 + max(10, 6) + 3 = 17.
        assert_eq!(t.cycles, 17);
        assert_eq!(t.compute_cycles, 13);
        assert_eq!(t.transfer_cycles, 10);
        assert_eq!(t.fill_cycles, 4);
        assert_eq!(t.tiles, 2);
        assert_eq!(t.overflows, 0);
        assert_eq!(t.input_peak, 24);
    }

    #[test]
    fn stream_tiles_serializes_overflowing_fetches() {
        // Half the input buffer is 8 bytes; both tiles overflow it, so
        // neither fetch double-buffers: cycles = T0 + (C0 + T1) + C1.
        let sram = plan_cfg(16, 200);
        let demands = [
            TileDemand {
                compute: 10,
                input_bytes: 16,
                weight_bytes: 0,
            },
            TileDemand {
                compute: 3,
                input_bytes: 24,
                weight_bytes: 0,
            },
        ];
        let t = stream_tiles(&sram, 4.0, &demands);
        assert_eq!(t.cycles, 4 + 10 + 6 + 3);
        assert_eq!(t.fill_cycles, 10);
        assert_eq!(t.overflows, 2);
    }

    #[test]
    fn stream_tiles_lower_bound_holds() {
        let sram = plan_cfg(128, 128);
        let demands: Vec<TileDemand> = (0..7)
            .map(|i| TileDemand {
                compute: (i as u64 * 13) % 29,
                input_bytes: (i as u64 * 31) % 90,
                weight_bytes: (i as u64 * 17) % 70,
            })
            .collect();
        let t = stream_tiles(&sram, 3.0, &demands);
        assert!(t.cycles >= t.compute_cycles);
        assert!(t.cycles >= t.transfer_cycles);
        assert!(t.fill_cycles <= t.transfer_cycles);
        assert_eq!(stream_tiles(&sram, 3.0, &[]).cycles, 0);
    }
}
