//! SRAM buffer models (Fig 3's input / weight / partial-sum / output
//! buffers with their controllers).
//!
//! The buffers are accounting models: they track resident bytes, peak
//! occupancy and overflow-driven refetches — enough to reproduce the
//! paper's architectural numbers without RTL-level port modelling.

/// One SRAM buffer with a capacity and occupancy/traffic counters.
#[derive(Debug, Clone)]
pub struct SramBuffer {
    pub name: &'static str,
    pub capacity_bytes: usize,
    resident_bytes: usize,
    /// Peak resident bytes observed.
    pub peak_bytes: usize,
    /// Total bytes written into the buffer (fill traffic).
    pub bytes_filled: u64,
    /// Fills rejected for capacity (each forces a DRAM refetch round).
    pub overflows: u64,
}

impl SramBuffer {
    pub fn new(name: &'static str, capacity_bytes: usize) -> SramBuffer {
        SramBuffer {
            name,
            capacity_bytes,
            resident_bytes: 0,
            peak_bytes: 0,
            bytes_filled: 0,
            overflows: 0,
        }
    }

    /// Try to make `bytes` resident. Returns `true` if they fit alongside
    /// the current contents; on `false` the caller must evict and refetch
    /// (counted in `overflows`).
    pub fn fill(&mut self, bytes: usize) -> bool {
        if self.resident_bytes + bytes > self.capacity_bytes {
            self.overflows += 1;
            return false;
        }
        self.resident_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.resident_bytes);
        self.bytes_filled += bytes as u64;
        true
    }

    /// Evict everything (context switch to a new tile/layer).
    pub fn clear(&mut self) {
        self.resident_bytes = 0;
    }

    /// Currently resident bytes.
    pub fn resident(&self) -> usize {
        self.resident_bytes
    }

    /// Whether `bytes` would fit in an empty buffer at all.
    pub fn fits_empty(&self, bytes: usize) -> bool {
        bytes <= self.capacity_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_and_peak_tracking() {
        let mut b = SramBuffer::new("input", 100);
        assert!(b.fill(60));
        assert!(b.fill(30));
        assert_eq!(b.resident(), 90);
        assert_eq!(b.peak_bytes, 90);
        assert!(!b.fill(20)); // would exceed
        assert_eq!(b.overflows, 1);
        b.clear();
        assert_eq!(b.resident(), 0);
        assert_eq!(b.peak_bytes, 90); // peak persists
        assert!(b.fill(20));
        assert_eq!(b.bytes_filled, 110);
    }

    #[test]
    fn fits_empty_is_capacity_check() {
        let b = SramBuffer::new("w", 64);
        assert!(b.fits_empty(64));
        assert!(!b.fits_empty(65));
    }
}
