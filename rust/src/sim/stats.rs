//! Cycle/work/traffic counters — every number in the paper's figures is
//! derived from these.

use super::dram::DramTraffic;
use crate::util::json::Json;

/// Roofline classification of a layer under the tiled memory model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemBound {
    /// The PE arrays dominate: compute cycles >= transfer cycles.
    Compute,
    /// The DRAM bus dominates: transfer cycles > compute cycles.
    Memory,
}

impl MemBound {
    /// Label used in reports (`"compute"` / `"memory"`).
    pub fn label(&self) -> &'static str {
        match self {
            MemBound::Compute => "compute",
            MemBound::Memory => "memory",
        }
    }
}

/// Statistics of one simulated layer (or an accumulated network run).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct SimStats {
    /// Compute cycles consumed (the paper's primary metric).
    pub cycles: u64,
    /// Vector pairs issued to the PE arrays (busy issue slots, summed over
    /// arrays — one array-cycle each).
    pub issued_pairs: u64,
    /// Issue slots where an array idled waiting for the slowest array in
    /// its group (multi-array sync loss).
    pub sync_stall_slots: u64,
    /// Pairs skipped because the input vector was all-zero.
    pub skipped_input: u64,
    /// Pairs skipped because the weight vector was all-zero (counted for
    /// pairs whose input vector was nonzero; the overlap is attributed to
    /// the input).
    pub skipped_weight: u64,
    /// Issued pairs whose output column fell outside the plane (X slots).
    pub boundary_pairs: u64,
    /// Scalar MACs performed (R*C per issued pair).
    pub macs: u64,
    /// Context-switch overhead cycles charged.
    pub overhead_cycles: u64,
    /// Pure-compute cycles: under [`crate::sim::config::MemModel::Tiled`]
    /// the tile-synchronized array occupancy, under `Ideal` equal to
    /// [`Self::cycles`].
    pub compute_cycles: u64,
    /// DRAM transfer cycles demanded across all tiles (input + weight +
    /// index traffic at the configured bandwidth; 0 under `Ideal`).
    pub transfer_cycles: u64,
    /// Transfer cycles that could not hide behind compute (prologue fills
    /// and overflowing tiles).
    pub fill_cycles: u64,
    /// Tiles executed by the tiled memory model (0 under `Ideal`).
    pub tiles: u64,
    /// SRAM capacity overflows observed while streaming tiles.
    pub sram_overflows: u64,
    /// External memory traffic.
    pub dram: DramTraffic,
    /// Peak input-buffer residency (compressed), bytes.
    pub sram_input_peak: u64,
    /// Peak weight-buffer residency (compressed, one filter group), bytes.
    pub sram_weight_peak: u64,
    /// Peak partial-sum-buffer residency, bytes.
    pub sram_psum_peak: u64,
}

impl SimStats {
    /// Merge layer stats into a running total.
    pub fn merge(&mut self, other: &SimStats) {
        self.cycles += other.cycles;
        self.issued_pairs += other.issued_pairs;
        self.sync_stall_slots += other.sync_stall_slots;
        self.skipped_input += other.skipped_input;
        self.skipped_weight += other.skipped_weight;
        self.boundary_pairs += other.boundary_pairs;
        self.macs += other.macs;
        self.overhead_cycles += other.overhead_cycles;
        self.compute_cycles += other.compute_cycles;
        self.transfer_cycles += other.transfer_cycles;
        self.fill_cycles += other.fill_cycles;
        self.tiles += other.tiles;
        self.sram_overflows += other.sram_overflows;
        self.dram.merge(&other.dram);
        self.sram_input_peak = self.sram_input_peak.max(other.sram_input_peak);
        self.sram_weight_peak = self.sram_weight_peak.max(other.sram_weight_peak);
        self.sram_psum_peak = self.sram_psum_peak.max(other.sram_psum_peak);
    }

    /// Total pairs skipped by zero-vector elimination.
    pub fn skipped_pairs(&self) -> u64 {
        self.skipped_input + self.skipped_weight
    }

    /// Which resource bounds this layer: memory when the DRAM bus demands
    /// more cycles than the arrays do. Always `Compute` under the ideal
    /// memory model (transfer cycles are zero there).
    pub fn bound(&self) -> MemBound {
        if self.transfer_cycles > self.compute_cycles {
            MemBound::Memory
        } else {
            MemBound::Compute
        }
    }

    /// Cycles the arrays spent waiting on DRAM (0 under the ideal model).
    pub fn mem_stall_cycles(&self) -> u64 {
        self.cycles.saturating_sub(self.compute_cycles)
    }

    /// Fraction of total cycles the DRAM bus was busy (0 under the ideal
    /// model; approaches 1 for memory-bound layers). Bounded by 1 because
    /// the tiled model guarantees `cycles >= transfer_cycles`.
    pub fn bw_utilization(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.transfer_cycles.min(self.cycles) as f64 / self.cycles as f64
        }
    }

    /// PE issue-slot utilization: busy slots / (busy + sync stalls).
    pub fn utilization(&self) -> f64 {
        let total = self.issued_pairs + self.sync_stall_slots;
        if total == 0 {
            0.0
        } else {
            self.issued_pairs as f64 / total as f64
        }
    }

    /// Serialize for reports.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("cycles", self.cycles)
            .set("issued_pairs", self.issued_pairs)
            .set("sync_stall_slots", self.sync_stall_slots)
            .set("skipped_input", self.skipped_input)
            .set("skipped_weight", self.skipped_weight)
            .set("boundary_pairs", self.boundary_pairs)
            .set("macs", self.macs)
            .set("overhead_cycles", self.overhead_cycles)
            .set("compute_cycles", self.compute_cycles)
            .set("transfer_cycles", self.transfer_cycles)
            .set("fill_cycles", self.fill_cycles)
            .set("mem_stall_cycles", self.mem_stall_cycles())
            .set("tiles", self.tiles)
            .set("sram_overflows", self.sram_overflows)
            .set("bound", self.bound().label())
            .set("bw_utilization", self.bw_utilization())
            .set("utilization", self.utilization())
            .set("dram_total_bytes", self.dram.total())
            .set("sram_input_peak", self.sram_input_peak)
            .set("sram_weight_peak", self.sram_weight_peak)
            .set("sram_psum_peak", self.sram_psum_peak);
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates_everything() {
        let a = SimStats {
            cycles: 10,
            issued_pairs: 8,
            sync_stall_slots: 2,
            skipped_input: 3,
            skipped_weight: 1,
            boundary_pairs: 1,
            macs: 120,
            overhead_cycles: 2,
            compute_cycles: 7,
            transfer_cycles: 3,
            fill_cycles: 1,
            tiles: 2,
            sram_overflows: 1,
            dram: DramTraffic {
                input_read: 5,
                ..Default::default()
            },
            sram_input_peak: 10,
            sram_weight_peak: 20,
            sram_psum_peak: 30,
        };
        let mut t = SimStats::default();
        t.merge(&a);
        t.merge(&a);
        assert_eq!(t.cycles, 20);
        assert_eq!(t.macs, 240);
        assert_eq!(t.skipped_pairs(), 8);
        assert_eq!(t.dram.input_read, 10);
        assert_eq!(t.compute_cycles, 14);
        assert_eq!(t.transfer_cycles, 6);
        assert_eq!(t.fill_cycles, 2);
        assert_eq!(t.tiles, 4);
        assert_eq!(t.sram_overflows, 2);
        assert_eq!(t.mem_stall_cycles(), 6);
    }

    #[test]
    fn bound_and_bw_utilization_classify() {
        let mut s = SimStats::default();
        assert_eq!(s.bound(), MemBound::Compute);
        assert_eq!(s.bw_utilization(), 0.0);
        s.cycles = 10;
        s.compute_cycles = 8;
        s.transfer_cycles = 4;
        assert_eq!(s.bound(), MemBound::Compute);
        assert!((s.bw_utilization() - 0.4).abs() < 1e-12);
        s.transfer_cycles = 9;
        assert_eq!(s.bound(), MemBound::Memory);
        assert_eq!(MemBound::Memory.label(), "memory");
        assert_eq!(MemBound::Compute.label(), "compute");
    }

    #[test]
    fn utilization_bounds() {
        let mut s = SimStats::default();
        assert_eq!(s.utilization(), 0.0);
        s.issued_pairs = 3;
        s.sync_stall_slots = 1;
        assert!((s.utilization() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn json_has_key_fields() {
        let s = SimStats {
            cycles: 42,
            ..Default::default()
        };
        let j = s.to_json();
        assert_eq!(j.get("cycles").unwrap().as_usize(), Some(42));
        assert!(j.get("utilization").is_some());
    }
}
