//! Accelerator configuration: PE-array geometry, SRAM capacities, clock and
//! DRAM bandwidth. The two paper configurations are provided as constants.

/// PE-array geometry `[B, R, C]`: `B` independent arrays, each `R` rows ×
/// `C` columns. `R` is the input-activation vector length; `C` must equal
/// the kernel height (3 for VGG) for full utilization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeConfig {
    /// Number of PE arrays (filters processed in parallel).
    pub arrays: usize,
    /// Rows per array = input vector length (14 or 7 in the paper).
    pub rows: usize,
    /// Columns per array = weight vector length (kernel height, 3).
    pub cols: usize,
}

impl PeConfig {
    /// The paper's `[4, 14, 3]` configuration (168 PEs).
    pub const PAPER_4_14_3: PeConfig = PeConfig {
        arrays: 4,
        rows: 14,
        cols: 3,
    };

    /// The paper's `[8, 7, 3]` configuration (168 PEs).
    pub const PAPER_8_7_3: PeConfig = PeConfig {
        arrays: 8,
        rows: 7,
        cols: 3,
    };

    /// Total PEs (`B * R * C`); both paper configs give 168.
    pub fn total_pes(&self) -> usize {
        self.arrays * self.rows * self.cols
    }

    /// Label used in reports, e.g. `[4,14,3]`.
    pub fn label(&self) -> String {
        format!("[{},{},{}]", self.arrays, self.rows, self.cols)
    }
}

/// SRAM buffer capacities in bytes (Fig 3's input/weight/partial-sum/output
/// buffers). Defaults are sized for VGG-16 working sets at 16-bit words,
/// comparable to the on-chip storage of contemporaneous designs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SramConfig {
    pub input_bytes: usize,
    pub weight_bytes: usize,
    pub psum_bytes: usize,
    pub output_bytes: usize,
    /// Bytes per stored element (16-bit fixed point, as typical for
    /// inference accelerators of this generation).
    pub bytes_per_elem: usize,
}

impl Default for SramConfig {
    fn default() -> Self {
        SramConfig {
            input_bytes: 64 * 1024,
            weight_bytes: 128 * 1024,
            psum_bytes: 32 * 1024,
            output_bytes: 64 * 1024,
            bytes_per_elem: 2,
        }
    }
}

/// Memory-model selector for the cycle accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemModel {
    /// Infinite SRAM, zero transfer time: pure compute cycles. This is the
    /// pre-tiling behavior, kept reachable for comparisons and pinned
    /// bit-for-bit by `tests/memory_model.rs`.
    Ideal,
    /// Tiled, double-buffered SRAM/DRAM model: each layer splits into
    /// SRAM-sized tiles (input strips × filter groups) and every tile is
    /// charged `max(compute, DRAM transfer)` with a prologue fill — see
    /// [`crate::sim::sram::stream_tiles`].
    Tiled,
}

impl MemModel {
    /// Parse a CLI flag value (`ideal` / `tiled`).
    pub fn parse(s: &str) -> Option<MemModel> {
        match s {
            "ideal" => Some(MemModel::Ideal),
            "tiled" => Some(MemModel::Tiled),
            _ => None,
        }
    }

    /// Label used in reports and cache keys.
    pub fn label(&self) -> &'static str {
        match self {
            MemModel::Ideal => "ideal",
            MemModel::Tiled => "tiled",
        }
    }
}

/// Payload precision for the CVF compressed streams (CLI `--precision`).
///
/// The index side of the format is unaffected (2-byte vector indices
/// either way); precision scales the *payload* words. [`Precision::F32`]
/// is the exact functional path, pinned bit-identical across PRs; the
/// fixed-point modes fake-quantize weights at compile time and
/// activations at layer boundaries against per-layer calibrated scales
/// (`sparse::vector_format::calibrated_scale`), and narrow
/// `SramConfig::bytes_per_elem` so the tiled memory model, the DRAM
/// traffic accounting and every dense/ideal baseline all carry the same
/// precision-scaled floor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Exact f32 payloads; modeled at the historical 16-bit storage
    /// width, so every pre-existing report stays bit-identical.
    F32,
    /// 16-bit fixed point (same 2-byte storage as the historical model,
    /// but functionally quantized).
    Int16,
    /// 8-bit fixed point: half the payload traffic of the 16-bit model.
    Int8,
}

impl Precision {
    /// Parse a CLI flag value (`f32` / `int16` / `int8`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" => Some(Precision::F32),
            "int16" | "i16" => Some(Precision::Int16),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Label used in reports and cache keys.
    pub fn label(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int16 => "int16",
            Precision::Int8 => "int8",
        }
    }

    /// Payload bytes per stored element under this precision. `F32`
    /// keeps the historical 16-bit modeled width (the pinned baseline);
    /// the fixed-point modes store what they quantize to.
    pub fn bytes_per_elem(&self) -> usize {
        match self {
            Precision::F32 | Precision::Int16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// Largest representable quantized magnitude (`2^(bits-1) - 1`);
    /// `None` for the exact f32 path.
    pub fn qmax(&self) -> Option<f32> {
        match self {
            Precision::F32 => None,
            Precision::Int16 => Some(32767.0),
            Precision::Int8 => Some(127.0),
        }
    }
}

/// Full simulator configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimConfig {
    pub pe: PeConfig,
    pub sram: SramConfig,
    /// Clock frequency in MHz (for latency-in-seconds reporting only;
    /// speedups are clock-independent).
    pub freq_mhz: f64,
    /// DRAM bandwidth in bytes/cycle (traffic accounting).
    pub dram_bytes_per_cycle: f64,
    /// Extra cycles charged when the accumulator drains a strip's partial
    /// sums and the array switches (c, strip, filter-group) context.
    /// The PE pipeline depth is small; default 2 (multiply + accumulate).
    pub context_switch_cycles: u64,
    /// Host worker threads for the simulation engine itself (the parallel
    /// functional dataflow and the group-timing fan-out). `0` = use every
    /// available core. This is a *simulator* knob: cycle counts and
    /// functional outputs are identical for every thread count.
    pub threads: usize,
    /// Memory model for the cycle accounting: [`MemModel::Tiled`] (the
    /// default) charges SRAM-sized tiles `max(compute, transfer)` with
    /// double-buffered fills; [`MemModel::Ideal`] reports pure compute
    /// cycles (infinite SRAM, zero transfer time).
    pub mem_model: MemModel,
    /// Verification knob: disable the scheduler's analytic (closed-form)
    /// fast paths and always run the exact per-vector/per-strip walk.
    /// Cycle counts and statistics are bit-identical either way — pinned
    /// by `sim::scheduler` tests and `tests/memory_model.rs` — so this
    /// only trades speed; the benches use it to measure the fast path.
    pub exact_scheduler: bool,
    /// CVF payload precision (CLI `--precision`); [`Precision::F32`] is
    /// the pinned exact path. Set via [`SimConfig::with_precision`] so
    /// [`SramConfig::bytes_per_elem`] stays consistent with it.
    pub precision: Precision,
    /// Fused strip execution (per-layer; set by the engine when the
    /// producing conv's output strip stays resident in input SRAM): the
    /// layer's input feature map is charged zero DRAM traffic — the
    /// scheduler's traffic accounting, the tiled demand walk and the
    /// dense baseline (`baselines::dense::dense_tile_demands`) all see
    /// the same eliminated transfer so the floors stay comparable.
    pub fused_input_resident: bool,
}

impl SimConfig {
    /// Paper configuration `[4, 14, 3]` with default memories.
    pub fn paper_4_14_3() -> SimConfig {
        SimConfig {
            pe: PeConfig::PAPER_4_14_3,
            sram: SramConfig::default(),
            freq_mhz: 500.0,
            dram_bytes_per_cycle: 8.0,
            context_switch_cycles: 2,
            threads: 0,
            mem_model: MemModel::Tiled,
            exact_scheduler: false,
            precision: Precision::F32,
            fused_input_resident: false,
        }
    }

    /// Paper configuration `[8, 7, 3]` with default memories.
    pub fn paper_8_7_3() -> SimConfig {
        SimConfig {
            pe: PeConfig::PAPER_8_7_3,
            ..Self::paper_4_14_3()
        }
    }

    /// Both paper configurations, labelled.
    pub fn paper_configs() -> Vec<SimConfig> {
        vec![Self::paper_4_14_3(), Self::paper_8_7_3()]
    }

    /// Resolve [`Self::threads`]: `0` means auto, via the crate-wide
    /// [`crate::util::resolve_threads`] (one worker per available core).
    pub fn effective_threads(&self) -> usize {
        crate::util::resolve_threads(self.threads)
    }

    /// Select a CVF payload precision, keeping the modeled storage width
    /// consistent: `sram.bytes_per_elem` follows
    /// [`Precision::bytes_per_elem`], so the tile planner, the DRAM
    /// traffic accounting, the psum/output sizing and every baseline
    /// inherit the narrower payloads automatically. `F32` leaves the
    /// historical 2-byte width untouched (the pinned exact path).
    pub fn with_precision(mut self, p: Precision) -> SimConfig {
        self.precision = p;
        self.sram.bytes_per_elem = p.bytes_per_elem();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_have_168_pes() {
        assert_eq!(PeConfig::PAPER_4_14_3.total_pes(), 168);
        assert_eq!(PeConfig::PAPER_8_7_3.total_pes(), 168);
    }

    #[test]
    fn labels_match_paper_notation() {
        assert_eq!(PeConfig::PAPER_4_14_3.label(), "[4,14,3]");
        assert_eq!(PeConfig::PAPER_8_7_3.label(), "[8,7,3]");
    }

    #[test]
    fn default_srams_positive() {
        let s = SramConfig::default();
        assert!(s.input_bytes > 0 && s.weight_bytes > 0);
        assert_eq!(s.bytes_per_elem, 2);
    }

    #[test]
    fn mem_model_parse_and_label_round_trip() {
        assert_eq!(MemModel::parse("ideal"), Some(MemModel::Ideal));
        assert_eq!(MemModel::parse("tiled"), Some(MemModel::Tiled));
        assert_eq!(MemModel::parse("bogus"), None);
        assert_eq!(MemModel::Ideal.label(), "ideal");
        assert_eq!(MemModel::Tiled.label(), "tiled");
        // The paper configs default to the tiled (memory-aware) model.
        assert_eq!(SimConfig::paper_4_14_3().mem_model, MemModel::Tiled);
    }

    #[test]
    fn precision_parse_label_and_widths() {
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("int16"), Some(Precision::Int16));
        assert_eq!(Precision::parse("i8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::Int8.label(), "int8");
        assert_eq!(Precision::F32.bytes_per_elem(), 2); // historical width
        assert_eq!(Precision::Int16.bytes_per_elem(), 2);
        assert_eq!(Precision::Int8.bytes_per_elem(), 1);
        assert_eq!(Precision::Int8.qmax(), Some(127.0));
        assert_eq!(Precision::F32.qmax(), None);
    }

    #[test]
    fn with_precision_keeps_storage_width_consistent() {
        let base = SimConfig::paper_4_14_3();
        // F32 is the identity on the whole config (pinned exact path).
        assert_eq!(base.with_precision(Precision::F32), base);
        assert_eq!(
            base.with_precision(Precision::Int16).sram.bytes_per_elem,
            2
        );
        let int8 = base.with_precision(Precision::Int8);
        assert_eq!(int8.sram.bytes_per_elem, 1);
        assert_eq!(int8.precision, Precision::Int8);
        // Everything else is untouched.
        assert_eq!(int8.sram.input_bytes, base.sram.input_bytes);
        assert_eq!(int8.pe, base.pe);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        let mut cfg = SimConfig::paper_8_7_3();
        assert!(cfg.effective_threads() >= 1);
        cfg.threads = 3;
        assert_eq!(cfg.effective_threads(), 3);
    }
}
