//! Artifact manifest: what `make artifacts` produced and where.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled conv bucket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub name: String,
    /// `"ref"` (lax.conv) or `"vscnn"` (Pallas column-dataflow kernel).
    pub kind: String,
    pub file: String,
    pub c_in: usize,
    pub c_out: usize,
    pub h: usize,
    pub w: usize,
    pub pad: usize,
    pub stride: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let field = |k: &str| -> Result<&Json> {
                a.get(k).ok_or_else(|| anyhow!("artifact missing '{k}'"))
            };
            let s = |k: &str| -> Result<String> {
                Ok(field(k)?
                    .as_str()
                    .ok_or_else(|| anyhow!("'{k}' not a string"))?
                    .to_string())
            };
            let n = |k: &str| -> Result<usize> {
                field(k)?.as_usize().ok_or_else(|| anyhow!("'{k}' not a number"))
            };
            artifacts.push(ArtifactInfo {
                name: s("name")?,
                kind: s("kind")?,
                file: s("file")?,
                c_in: n("c_in")?,
                c_out: n("c_out")?,
                h: n("h")?,
                w: n("w")?,
                pad: n("pad")?,
                stride: n("stride")?,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Find the artifact of `kind` matching a conv layer's geometry.
    pub fn find(&self, kind: &str, c_in: usize, c_out: usize, h: usize, w: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind && a.c_in == c_in && a.c_out == c_out && a.h == h && a.w == w)
    }

    /// Absolute path of an artifact's HLO text.
    pub fn path_of(&self, art: &ArtifactInfo) -> PathBuf {
        self.dir.join(&art.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parse_and_find() {
        let dir = std::env::temp_dir().join(format!("vscnn_manifest_{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"network":"vgg16","artifacts":[
                {"name":"ref_c3_h8_w8_k4","kind":"ref","file":"ref_c3_h8_w8_k4.hlo.txt",
                 "c_in":3,"c_out":4,"h":8,"w":8,"pad":1,"stride":1}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert!(m.find("ref", 3, 4, 8, 8).is_some());
        assert!(m.find("vscnn", 3, 4, 8, 8).is_none());
        assert!(m.find("ref", 3, 4, 8, 9).is_none());
        let p = m.path_of(&m.artifacts[0]);
        assert!(p.ends_with("ref_c3_h8_w8_k4.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_informative() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn malformed_manifest_rejected() {
        let dir = std::env::temp_dir().join(format!("vscnn_badmanifest_{}", std::process::id()));
        write_manifest(&dir, r#"{"artifacts": [{"name": "x"}]}"#);
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "not json");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
