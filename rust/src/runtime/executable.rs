//! Executable cache: compile each HLO artifact once on the PJRT CPU
//! client, then execute conv layers with zero-copy-ish literal plumbing.
//!
//! The real PJRT path needs the `xla` bindings, which are not vendored in
//! the offline build environment; it is gated behind the `pjrt` cargo
//! feature. The default build ships a stub [`Runtime`] with the same API:
//! manifest parsing works, `new`/execution return a clear error, and every
//! caller (coordinator backend, CLI, e2e example) falls back to the rust
//! conv paths gracefully.

use super::artifacts::Manifest;

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::Runtime;

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::Manifest;
    use crate::runtime::artifacts::ArtifactInfo;
    use crate::tensor::Tensor;
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::sync::Mutex;

    /// PJRT-backed executor over an artifact manifest.
    ///
    /// Interior mutability so the coordinator can share one `Runtime` across
    /// worker threads (`xla::PjRtLoadedExecutable` execution is thread-safe;
    /// the cache map is guarded).
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        /// Create a CPU PJRT client over `artifacts_dir`.
        pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
            let manifest = Manifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
            Ok(Runtime {
                client,
                manifest,
                cache: Mutex::new(HashMap::new()),
            })
        }

        /// The manifest in use.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn load(&self, art: &ArtifactInfo) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.lock().unwrap().get(&art.name) {
                return Ok(exe.clone());
            }
            let path = self.manifest.path_of(art);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", art.name))?;
            let exe = std::sync::Arc::new(exe);
            self.cache
                .lock()
                .unwrap()
                .insert(art.name.clone(), exe.clone());
            Ok(exe)
        }

        /// Execute a conv bucket: `x [C,H,W]`, `w [K,C,3,3]`, `b [K]` →
        /// pre-ReLU `[K, H_out, W_out]`.
        pub fn run_conv(
            &self,
            art: &ArtifactInfo,
            x: &Tensor,
            w: &Tensor,
            b: &[f32],
        ) -> Result<Tensor> {
            anyhow::ensure!(
                x.shape() == [art.c_in, art.h, art.w],
                "input shape {:?} != artifact [{}, {}, {}]",
                x.shape(),
                art.c_in,
                art.h,
                art.w
            );
            anyhow::ensure!(
                w.shape()[0] == art.c_out && w.shape()[1] == art.c_in,
                "weight shape {:?} mismatches artifact {}",
                w.shape(),
                art.name
            );
            let exe = self.load(art)?;
            let to_lit = |t: &[f32], dims: &[i64]| -> Result<xla::Literal> {
                xla::Literal::vec1(t)
                    .reshape(dims)
                    .map_err(|e| anyhow!("literal reshape {dims:?}: {e}"))
            };
            let xl = to_lit(x.data(), &[art.c_in as i64, art.h as i64, art.w as i64])?;
            let wl = to_lit(
                w.data(),
                &[
                    art.c_out as i64,
                    art.c_in as i64,
                    w.shape()[2] as i64,
                    w.shape()[3] as i64,
                ],
            )?;
            let bl = xla::Literal::vec1(b);
            let result = exe
                .execute::<xla::Literal>(&[xl, wl, bl])
                .map_err(|e| anyhow!("executing {}: {e}", art.name))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result: {e}"))?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
            let values = out
                .to_vec::<f32>()
                .map_err(|e| anyhow!("literal to_vec: {e}"))?;
            let h_out = art.h + 2 * art.pad - (w.shape()[2] - 1) - 1 + 1;
            let w_out = art.w + 2 * art.pad - (w.shape()[3] - 1) - 1 + 1;
            anyhow::ensure!(
                values.len() == art.c_out * h_out * w_out,
                "result length {} != {}x{}x{}",
                values.len(),
                art.c_out,
                h_out,
                w_out
            );
            Ok(Tensor::from_vec(&[art.c_out, h_out, w_out], values))
        }

        /// Convenience: find + run by geometry, preferring `kind`.
        pub fn run_conv_by_shape(
            &self,
            kind: &str,
            x: &Tensor,
            w: &Tensor,
            b: &[f32],
        ) -> Result<Tensor> {
            let (c_in, h, ww) = (x.shape()[0], x.shape()[1], x.shape()[2]);
            let c_out = w.shape()[0];
            let art = self
                .manifest
                .find(kind, c_in, c_out, h, ww)
                .with_context(|| {
                    format!("no '{kind}' artifact for [C={c_in},H={h},W={ww}]→K={c_out}; re-run `make artifacts`")
                })?
                .clone();
            self.run_conv(&art, x, w, b)
        }
    }

    // PJRT executables and client handles are safe to share across threads
    // for execution; the xla crate just doesn't mark them. The cache Mutex
    // guards the only interior mutation.
    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::Manifest;
    use crate::runtime::artifacts::ArtifactInfo;
    use crate::tensor::Tensor;
    use anyhow::{bail, Result};

    /// Stub runtime used when the crate is built without the `pjrt`
    /// feature: [`Runtime::new`] validates the manifest (so error paths and
    /// diagnostics stay testable) and then reports that execution is
    /// unavailable.
    pub struct Runtime {
        manifest: Manifest,
    }

    impl Runtime {
        /// Always fails after loading the manifest: the PJRT client needs
        /// the `xla` bindings, which this build does not link.
        pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
            let manifest = Manifest::load(artifacts_dir)?;
            bail!(
                "PJRT runtime unavailable: built without the `pjrt` cargo feature \
                 ({} artifacts parsed at {:?}); use the rust conv backends instead",
                manifest.artifacts.len(),
                manifest.dir
            );
        }

        /// The manifest in use.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            "pjrt-disabled".to_string()
        }

        /// Unreachable in practice (`new` never returns a stub instance);
        /// present so call sites typecheck identically with and without the
        /// feature.
        pub fn run_conv(
            &self,
            art: &ArtifactInfo,
            _x: &Tensor,
            _w: &Tensor,
            _b: &[f32],
        ) -> Result<Tensor> {
            bail!("cannot execute {}: built without the `pjrt` feature", art.name)
        }

        /// See [`Self::run_conv`].
        pub fn run_conv_by_shape(
            &self,
            kind: &str,
            _x: &Tensor,
            _w: &Tensor,
            _b: &[f32],
        ) -> Result<Tensor> {
            bail!("cannot execute '{kind}' artifact: built without the `pjrt` feature")
        }
    }
}

#[cfg(test)]
mod tests {
    //! Integration tests against real artifacts live in
    //! `rust/tests/runtime_pjrt.rs` (they need `make artifacts`). Here we
    //! only test the error paths that need no artifacts.
    use super::*;

    #[test]
    fn missing_dir_errors() {
        assert!(Runtime::new("/nonexistent/artifacts").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_reports_disabled_feature_with_valid_manifest() {
        let dir = std::env::temp_dir().join(format!("vscnn_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"network":"vgg16","artifacts":[]}"#,
        )
        .unwrap();
        let err = Runtime::new(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
