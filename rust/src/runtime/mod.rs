//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Python never runs here — the artifacts are self-contained XLA programs
//! compiled once per process by the PJRT CPU client (see
//! /opt/xla-example/load_hlo for the reference wiring). The runtime gives
//! the coordinator a fast functional conv (`ref_*` artifacts, XLA's native
//! conv) and the Pallas-kernel path (`vscnn_*`) for cross-validation.

pub mod artifacts;
pub mod executable;

pub use artifacts::{ArtifactInfo, Manifest};
pub use executable::Runtime;
