#!/usr/bin/env python3
"""Validate a Chrome trace_event JSON file written by `--trace-out`.

Usage: check_trace.py TRACE.json [TRACE2.json ...] [--min-events N]

Checks, per file:

* the document parses and has a `traceEvents` list (plus the
  `otherData.dropped_events` counter the exporter always writes);
* every event carries name/cat/ph/pid/tid/ts, with a phase the exporter
  emits (X, i, C, M) or Perfetto accepts from hand-edits (B, E);
* complete ("X") events have a non-negative `dur` and instants carry a
  scope (`"s"`);
* on every (pid, tid) lane the X intervals are properly nested: sorted
  by (ts, -dur), each event either fits inside the enclosing one or
  starts after it ends — overlapping-but-not-nested spans mean a broken
  emitter and render as garbage in the Perfetto UI.

Exits 1 on the first structural problem; used by the CI observability
smoke against `vscnn simulate --trace-out` and the faulted serve run.
"""

import argparse
import json
import sys

KNOWN_PHASES = {"X", "B", "E", "i", "C", "M"}
REQUIRED = ("name", "cat", "ph", "pid", "tid", "ts")


def fail(path, msg):
    print(f"{path}: {msg}", file=sys.stderr)
    return 1


def check_nesting(path, events):
    """X intervals on one lane must nest like a call stack."""
    lanes = {}
    for ev in events:
        if ev["ph"] == "X":
            lanes.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for (pid, tid), evs in sorted(lanes.items()):
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack = []  # (start, end, name) of enclosing spans
        for ev in evs:
            start, end = ev["ts"], ev["ts"] + ev["dur"]
            while stack and start >= stack[-1][1]:
                stack.pop()
            if stack and end > stack[-1][1]:
                return fail(
                    path,
                    f"lane ({pid}, {tid}): span '{ev['name']}' "
                    f"[{start}, {end}) overlaps enclosing "
                    f"'{stack[-1][2]}' [{stack[-1][0]}, {stack[-1][1]}) "
                    f"without nesting inside it")
            stack.append((start, end, ev["name"]))
    return 0


def check_file(path, min_events):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return fail(path, f"cannot load: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return fail(path, "no traceEvents list")
    dropped = doc.get("otherData", {}).get("dropped_events")
    if not isinstance(dropped, int):
        return fail(path, "otherData.dropped_events missing")

    counts = {}
    for n, ev in enumerate(events):
        if not isinstance(ev, dict):
            return fail(path, f"event {n} is not an object")
        for key in REQUIRED:
            if key not in ev:
                return fail(path, f"event {n} ({ev.get('name')!r}) lacks '{key}'")
        ph = ev["ph"]
        if ph not in KNOWN_PHASES:
            return fail(path, f"event {n} has unknown phase {ph!r}")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                return fail(path, f"event {n} ('X') needs dur >= 0, got {ev.get('dur')!r}")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            return fail(path, f"event {n} ('i') needs a scope s in t/p/g")
        counts[ph] = counts.get(ph, 0) + 1

    payload = len(events) - counts.get("M", 0)
    if payload < min_events:
        return fail(path, f"only {payload} non-metadata events (< {min_events})")
    if check_nesting(path, events):
        return 1

    summary = " ".join(f"{ph}:{counts[ph]}" for ph in sorted(counts))
    print(f"{path}: OK — {len(events)} events ({summary}), {dropped} dropped")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("traces", nargs="+", help="trace_event JSON files")
    ap.add_argument("--min-events", type=int, default=1,
                    help="minimum non-metadata events per file (default 1)")
    args = ap.parse_args()
    return max(check_file(p, args.min_events) for p in args.traces)


if __name__ == "__main__":
    sys.exit(main())
