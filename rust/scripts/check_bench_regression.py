#!/usr/bin/env python3
"""Diff a freshly generated bench report against the committed baseline.

Usage: check_bench_regression.py NEW.json BASELINE.json [--threshold 0.10]

Compares the two `{"results": [...], "derived": {...}}` documents written
by `cargo bench --bench bench_sim_perf` / `bench_serve`:

* per-series `median_ns` — warns when a series got more than THRESHOLD
  slower than the committed run;
* throughput-style `derived` keys (anything ending in `_per_sec` plus
  `speedup_vs_scoped` and the `functional_speedup_*` family) — warns when
  one dropped by more than THRESHOLD.

Warn-only by design: bench hosts differ, so CI prints the table and the
warnings but never fails the build on them (pass --strict to exit 1 on
warnings instead, for local gating on one machine).
"""

import argparse
import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def series_medians(doc):
    return {r["name"]: r["median_ns"] for r in doc.get("results", [])}


def throughput_keys(derived):
    out = {}
    for key, val in derived.items():
        if not isinstance(val, (int, float)):
            continue
        if key.endswith("_per_sec") or key == "speedup_vs_scoped" or key.startswith(
            "functional_speedup_"
        ):
            out[key] = float(val)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", help="freshly generated bench JSON")
    ap.add_argument("baseline", help="committed previous run")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative regression that triggers a warning (default 0.10)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any warning fires")
    args = ap.parse_args()

    new, base = load(args.new), load(args.baseline)
    warnings = []

    print(f"{'series':44} {'baseline':>12} {'new':>12} {'ratio':>7}")
    new_med, base_med = series_medians(new), series_medians(base)
    for name in sorted(new_med):
        if name not in base_med or base_med[name] <= 0:
            continue
        ratio = new_med[name] / base_med[name]
        flag = ""
        if ratio > 1.0 + args.threshold:
            flag = "  <-- SLOWER"
            warnings.append(f"{name}: median {ratio:.2f}x the baseline")
        print(f"{name:44} {base_med[name]:>12} {new_med[name]:>12} {ratio:>6.2f}x{flag}")

    new_thr = throughput_keys(new.get("derived", {}))
    base_thr = throughput_keys(base.get("derived", {}))
    for key in sorted(new_thr):
        if key not in base_thr or base_thr[key] <= 0:
            continue
        ratio = new_thr[key] / base_thr[key]
        flag = ""
        if ratio < 1.0 - args.threshold:
            flag = "  <-- THROUGHPUT DROP"
            warnings.append(f"derived.{key}: {ratio:.2f}x the baseline")
        print(f"derived.{key:36} {base_thr[key]:>12.3f} {new_thr[key]:>12.3f} {ratio:>6.2f}x{flag}")

    if warnings:
        print(f"\nWARNING: {len(warnings)} series regressed more than "
              f"{args.threshold:.0%} vs {args.baseline}:", file=sys.stderr)
        for w in warnings:
            print(f"  - {w}", file=sys.stderr)
        if args.strict:
            return 1
    else:
        print(f"\nOK: no series regressed more than {args.threshold:.0%}.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
