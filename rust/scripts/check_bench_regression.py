#!/usr/bin/env python3
"""Diff freshly generated bench reports against their committed baselines.

Usage: check_bench_regression.py NEW.json BASELINE.json [NEW2.json BASELINE2.json ...]
                                 [--threshold 0.10] [--derived-threshold X]
                                 [--strict] [--strict-derived]

Takes one or more NEW/BASELINE pairs and compares each pair of
`{"results": [...], "derived": {...}}` documents written by
`cargo bench --bench bench_sim_perf` / `bench_serve` and by
`vscnn exp serve-scale` (`BENCH_serve_scale.json`):

* per-series `median_ns` — warns when a series got more than THRESHOLD
  slower than the committed run (and notes the ones that got faster);
* throughput-style `derived` keys (anything ending in `_per_sec` plus
  `speedup_vs_scoped` and the `functional_speedup_*` family) — warns when
  one dropped by more than the derived threshold (default: the series
  threshold), and notes improvements;
* the observability cost pair (`metrics_{off,on}_images_per_sec`, when the
  report carries it) — printed per report, with a warn-only note when the
  metrics registry costs more than 3%;
* the data-integrity cost (`checksum_overhead_frac`, written by
  `vscnn exp serve-sdc` into `BENCH_serve_sdc.json`) — printed per
  report, with a warn-only note when ABFT checksums + CVF validation
  cost more than 5% of clean goodput.

A missing NEW or BASELINE file skips that pair with a note (first-PR
bootstrap: the baseline does not exist yet).

Warn-only by design: bench hosts differ, so CI prints the table and the
warnings but never fails the build on them. Two gating modes exist:
`--strict` exits 1 on any warning (local gating on one machine);
`--strict-derived` exits 1 only when a *derived throughput key* dropped —
CI runs that one with `--derived-threshold 0.25`, a band loose enough for
shared runners while still catching real throughput collapses.
"""

import argparse
import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def series_medians(doc):
    return {r["name"]: r["median_ns"] for r in doc.get("results", [])}


def throughput_keys(derived):
    out = {}
    for key, val in derived.items():
        if not isinstance(val, (int, float)) or isinstance(val, bool):
            continue
        if key.endswith("_per_sec") or key == "speedup_vs_scoped" or key.startswith(
            "functional_speedup_"
        ):
            out[key] = float(val)
    return out


def report_metrics_overhead(doc, path, limit=0.03):
    """Surface the observability cost pair measured by bench_sim_perf
    (`obs/engine-execute-metrics-{off,on}`). Warn-only by design — never
    gates, even under --strict: the pair measures a sub-percent effect
    and is the noisiest number in the report."""
    derived = doc.get("derived", {})
    off = derived.get("metrics_off_images_per_sec")
    on = derived.get("metrics_on_images_per_sec")
    if not isinstance(off, (int, float)) or not isinstance(on, (int, float)):
        return
    if off <= 0 or on <= 0:
        return
    overhead = off / on - 1.0
    print(f"observability: {off:.2f} images/sec metrics-off vs {on:.2f} "
          f"metrics-on ({overhead:+.1%} overhead)")
    if overhead > limit:
        print(f"NOTE: {path}: metrics registry overhead {overhead:.1%} exceeds "
              f"{limit:.0%} (warn-only; the registry should be near-free when "
              f"idle)", file=sys.stderr)


def report_sdc_overhead(doc, path, limit=0.05):
    """Surface the data-integrity protection cost measured by
    `vscnn exp serve-sdc` (`derived.checksum_overhead_frac`: goodput
    lost to ABFT checksums + CVF validation at the lowest injected flip
    rate). Warn-only by design — never gates, even under --strict: the
    protection charge is a configured fraction plus queueing effects,
    and the goodput estimate rides on one seeded run."""
    derived = doc.get("derived", {})
    frac = derived.get("checksum_overhead_frac")
    if not isinstance(frac, (int, float)) or isinstance(frac, bool):
        return
    print(f"integrity: checksum-on goodput overhead {frac:+.1%}")
    if frac > limit:
        print(f"NOTE: {path}: integrity protection overhead {frac:.1%} exceeds "
              f"{limit:.0%} (warn-only; ABFT + validation should stay cheap)",
              file=sys.stderr)


def compare_pair(new_path, base_path, threshold, derived_threshold):
    """Print the comparison table for one NEW/BASELINE pair; return
    (series_warnings, derived_warnings, improvements)."""
    new, base = load(new_path), load(base_path)
    series_warnings, derived_warnings, improvements = [], [], []

    print(f"== {new_path} vs {base_path} ==")
    print(f"{'series':44} {'baseline':>12} {'new':>12} {'ratio':>7}")
    new_med, base_med = series_medians(new), series_medians(base)
    for name in sorted(new_med):
        if name not in base_med or base_med[name] <= 0:
            continue
        ratio = new_med[name] / base_med[name]
        flag = ""
        if ratio > 1.0 + threshold:
            flag = "  <-- SLOWER"
            series_warnings.append(
                f"{new_path}: {name}: median {ratio:.2f}x the baseline")
        elif ratio < 1.0 - threshold:
            flag = "  <-- FASTER"
            improvements.append(
                f"{new_path}: {name}: median down to {ratio:.2f}x the baseline")
        print(f"{name:44} {base_med[name]:>12} {new_med[name]:>12} {ratio:>6.2f}x{flag}")

    new_thr = throughput_keys(new.get("derived", {}))
    base_thr = throughput_keys(base.get("derived", {}))
    for key in sorted(new_thr):
        if key not in base_thr or base_thr[key] <= 0:
            continue
        ratio = new_thr[key] / base_thr[key]
        flag = ""
        if ratio < 1.0 - derived_threshold:
            flag = "  <-- THROUGHPUT DROP"
            derived_warnings.append(
                f"{new_path}: derived.{key}: {ratio:.2f}x the baseline")
        elif ratio > 1.0 + derived_threshold:
            flag = "  <-- IMPROVED"
            improvements.append(
                f"{new_path}: derived.{key}: up to {ratio:.2f}x the baseline")
        print(f"derived.{key:36} {base_thr[key]:>12.3f} {new_thr[key]:>12.3f} {ratio:>6.2f}x{flag}")
    report_metrics_overhead(new, new_path)
    report_sdc_overhead(new, new_path)
    return series_warnings, derived_warnings, improvements


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("pairs", nargs="+", metavar="NEW.json BASELINE.json",
                    help="one or more NEW BASELINE file pairs")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative series regression that triggers a warning "
                         "(default 0.10)")
    ap.add_argument("--derived-threshold", type=float, default=None,
                    help="relative drop in a derived throughput key that "
                         "triggers a warning (default: --threshold)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any warning fires")
    ap.add_argument("--strict-derived", action="store_true",
                    help="exit 1 only when a derived throughput key dropped "
                         "(series stay warn-only)")
    args = ap.parse_args()
    derived_threshold = (args.threshold if args.derived_threshold is None
                         else args.derived_threshold)

    if len(args.pairs) % 2 != 0:
        ap.error("expected an even number of files (NEW BASELINE pairs), "
                 f"got {len(args.pairs)}")

    series_warnings, derived_warnings, improvements = [], [], []
    for new_path, base_path in zip(args.pairs[::2], args.pairs[1::2]):
        missing = [p for p in (new_path, base_path) if not os.path.exists(p)]
        if missing:
            print(f"== {new_path} vs {base_path} ==")
            print(f"skipped: missing {', '.join(missing)} (no baseline yet?)")
            continue
        s, d, i = compare_pair(new_path, base_path, args.threshold,
                               derived_threshold)
        series_warnings.extend(s)
        derived_warnings.extend(d)
        improvements.extend(i)

    if improvements:
        print(f"\nIMPROVED: {len(improvements)} series/keys beat the baseline:")
        for i in improvements:
            print(f"  + {i}")

    warnings = series_warnings + derived_warnings
    if warnings:
        print(f"\nWARNING: {len(warnings)} series regressed "
              f"(series threshold {args.threshold:.0%}, derived threshold "
              f"{derived_threshold:.0%}):", file=sys.stderr)
        for w in warnings:
            print(f"  - {w}", file=sys.stderr)
        if args.strict:
            return 1
        if args.strict_derived and derived_warnings:
            return 1
    else:
        print(f"\nOK: no series regressed more than {args.threshold:.0%} "
              f"(derived: {derived_threshold:.0%}).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
